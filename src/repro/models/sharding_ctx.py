"""Activation-sharding context shared by model components.

Set by launch/dryrun/train under a mesh; no-op on single-device smoke tests.
Constraints are divisibility-guarded so the same model code runs everywhere.
"""
from __future__ import annotations

import jax

_ACT_SHARD = {"enabled": False, "dp": ("data",), "dp_size": 1,
              "model_size": 1, "sp": False}


def set_activation_sharding(enabled: bool, dp=("data",), dp_size: int = 1,
                            model_size: int = 1, sp: bool = False):
    """``sp=True`` additionally shards the sequence dim of the residual
    stream over 'model' (sequence parallelism): per-layer activation saves
    under remat shrink by the TP degree; XLA inserts the SP all-gather at
    layer entry (Korthikanti et al. pattern). Hillclimb lever — see
    EXPERIMENTS.md §Perf."""
    _ACT_SHARD.update(enabled=enabled, dp=tuple(dp), dp_size=dp_size,
                      model_size=model_size, sp=sp)


def constrain(x, *spec_entries):
    """with_sharding_constraint with divisibility guards.

    spec entries: 'dp' (data axes), 'model', None. An entry is dropped when
    it does not divide the corresponding dim.
    """
    if not _ACT_SHARD["enabled"]:
        return x
    from jax.sharding import PartitionSpec as P
    out = []
    for dim, e in zip(x.shape, spec_entries):
        if e == "dp":
            ok = dim % _ACT_SHARD["dp_size"] == 0 and dim > 1
            out.append(_ACT_SHARD["dp"] if ok else None)
        elif e == "model":
            out.append("model" if dim % _ACT_SHARD["model_size"] == 0
                       and dim > 1 else None)
        else:
            out.append(None)
    return jax.lax.with_sharding_constraint(x, P(*out))


def constrain_acts(x):
    if _ACT_SHARD["sp"] and x.ndim >= 3:
        return constrain(x, "dp", "model", *([None] * (x.ndim - 2)))
    return constrain(x, "dp", *([None] * (x.ndim - 1)))


def constrain_logits(x):
    return constrain(x, "dp", *([None] * (x.ndim - 2)), "model")
