"""Minimal functional module system: params as pytrees + logical-axis metadata.

A ``Builder`` interprets parameter declarations in one of three modes:
  · ``init``  — materialize arrays (CPU smoke tests, real training)
  · ``shape`` — ShapeDtypeStructs only (dry-run: no allocation, 90B-safe)
  · ``axes``  — logical sharding axes tuples (fed to distrib.sharding rules)

Module code declares each parameter exactly once; all three interpretations
stay structurally identical by construction.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Builder:
    def __init__(self, mode: str, key: Optional[jax.Array] = None,
                 dtype=jnp.float32):
        assert mode in ("init", "shape", "axes")
        self.mode = mode
        self.key = key
        self.dtype = dtype
        self._counter = 0

    def _next_key(self):
        self._counter += 1
        return jax.random.fold_in(self.key, self._counter)

    def param(self, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
              init: str = "normal", scale: Optional[float] = None,
              dtype=None):
        assert len(shape) == len(axes), (shape, axes)
        dtype = dtype or self.dtype
        if self.mode == "axes":
            return axes
        if self.mode == "shape":
            return jax.ShapeDtypeStruct(shape, dtype)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if scale is None:
            # fan-in scaled normal
            fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
            scale = 1.0 / np.sqrt(fan_in)
        return (scale * jax.random.normal(self._next_key(), shape)).astype(dtype)

    def vmapped(self, fn, n: int):
        """Build ``n`` stacked copies of a param subtree (scan-over-layers).

        Leaves get a leading dim of size ``n``; axes get a leading ``layer``
        (i.e. unsharded stacking) entry.
        """
        if self.mode == "axes":
            sub = fn(self)
            return jax.tree.map(lambda a: (None,) + tuple(a), sub,
                                is_leaf=lambda x: isinstance(x, tuple))
        if self.mode == "shape":
            sub = fn(self)
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), sub)
        keys = jax.random.split(self._next_key(), n)

        def one(k):
            b = Builder("init", k, self.dtype)
            return fn(b)

        return jax.vmap(one)(keys)


def make(init_fn, cfg, mode: str, key=None, dtype=jnp.float32):
    b = Builder(mode, key=key, dtype=dtype)
    return init_fn(b, cfg)
