"""Attention variants: GQA self-attention, MLA (latent), cross-attention.

All projections are stored flat ``(d_model, n*head_dim)`` so tensor-parallel
sharding of the output dim never hits head-count divisibility limits (see
distrib/sharding.py). KV caches are functional inputs/outputs.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope
from repro.models.module import Builder

NEG_INF = -1e30

# Blockwise-attention KV chunk size. The dry-run's cost-compile mode sets
# this to a huge value (single chunk) so XLA cost_analysis — which counts
# scan bodies once, not x trip count — sees the full attention FLOPs.
_FLASH_CHUNK = {"size": 512}


def set_flash_chunk(size: int):
    _FLASH_CHUNK["size"] = size


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_params(b: Builder, cfg: ArchConfig):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "wq": b.param((d, cfg.n_heads * hd), ("embed", "heads")),
        "wk": b.param((d, cfg.n_kv_heads * hd), ("embed", "kv")),
        "wv": b.param((d, cfg.n_kv_heads * hd), ("embed", "kv")),
        "wo": b.param((cfg.n_heads * hd, d), ("heads", "embed")),
    }


def blockwise_gqa(q, k, v, chunk: int = 0):
    """Causal online-softmax attention, scanned over KV chunks — never
    materializes the (S, T) score matrix. Pure XLA (compiles on any backend);
    the Pallas flash kernel (kernels/flash_attention) is the TPU analogue
    and is validated against the same math.

    q: (B,S,K,G,hd), k/v: (B,S,K,hd). Self-attention, positions = arange(S).
    """
    B, S, K, G, hd = q.shape
    chunk = min(chunk or _FLASH_CHUNK["size"], S)
    while S % chunk != 0:
        chunk //= 2
    c = S // chunk
    scale = 1.0 / jnp.sqrt(hd)
    kc = jnp.moveaxis(k.reshape(B, c, chunk, K, hd), 1, 0)   # (c,B,chunk,K,hd)
    vc = jnp.moveaxis(v.reshape(B, c, chunk, K, hd), 1, 0)
    q_pos = jnp.arange(S)

    @jax.checkpoint
    def body(carry, inp):
        # rematerialized in backward: per-chunk probabilities are never
        # stored across the scan (flash-attention backward semantics)
        m, l, acc = carry                                    # (B,K,G,S), ..., (B,S,K,G,hd)
        idx, k_blk, v_blk = inp
        s = jnp.einsum("bskgd,btkd->bkgst", q, k_blk).astype(jnp.float32)
        s = s * scale
        k_pos = idx * chunk + jnp.arange(chunk)
        mask = k_pos[None, :] <= q_pos[:, None]              # (S, chunk)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bskgd", p.astype(v_blk.dtype), v_blk)
        acc = acc * jnp.moveaxis(corr, 3, 1)[..., None].astype(acc.dtype) + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, K, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    acc0 = jnp.zeros((B, S, K, G, hd), v.dtype)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                  (jnp.arange(c), kc, vc))
    out = acc / jnp.maximum(jnp.moveaxis(l, 3, 1), 1e-30)[..., None].astype(acc.dtype)
    return out


def _gqa_scores_combine(q, k, v, mask):
    """q: (B,S,K,G,hd), k/v: (B,T,K,hd), mask: (S,T) or (B,S,T) bool."""
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    if mask.ndim == 2:
        mask_b = mask[None, None, None]
    else:
        mask_b = mask[:, None, None]
    scores = jnp.where(mask_b, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", probs, v)


def gqa_attention(p, cfg: ArchConfig, x, positions, cache=None,
                  cache_index=None, use_flash: bool = False):
    """Self-attention. Train/prefill: cache=None or returned fresh.
    Decode: cache=(k,v) of shape (B, S_max, K, hd), cache_index scalar.

    Returns (out, new_cache).
    """
    B, S, _ = x.shape
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    H = cfg.n_heads
    G = H // K
    q = (x @ p["wq"]).reshape(B, S, K, G, hd)
    k = (x @ p["wk"]).reshape(B, S, K, hd)
    v = (x @ p["wv"]).reshape(B, S, K, hd)
    q = apply_rope(q.reshape(B, S, H, hd), positions, cfg.rope_theta)
    q = q.reshape(B, S, K, G, hd)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        # causal full attention
        if use_flash and S > 1:
            out = blockwise_gqa(q, k, v)
        else:
            mask = jnp.tril(jnp.ones((S, S), bool))
            out = _gqa_scores_combine(q, k, v, mask)
        new_cache = (k, v)
    else:
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_index, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_index, axis=1)
        T = ck.shape[1]
        valid = jnp.arange(T)[None, :] <= positions[:, -1:]   # absolute positions
        mask = jnp.broadcast_to(valid[:, None, :], (B, S, T))
        out = _gqa_scores_combine(q, ck, cv, mask)
        new_cache = (ck, cv)
    out = out.reshape(B, S, H * hd)
    return out @ p["wo"], new_cache


def gqa_cache_spec(cfg: ArchConfig, batch: int, seq: int, dtype):
    shape = (batch, seq, cfg.n_kv_heads, cfg.resolved_head_dim)
    return (jax.ShapeDtypeStruct(shape, dtype),
            jax.ShapeDtypeStruct(shape, dtype))


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------

def mla_params(b: Builder, cfg: ArchConfig):
    d = cfg.d_model
    H = cfg.n_heads
    qr, kr = cfg.mla_q_rank, cfg.mla_kv_rank
    nd, rd, vd = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    return {
        "wq_a": b.param((d, qr), ("embed", "lora")),
        "q_norm": b.param((qr,), ("lora",), init="ones"),
        "wq_b": b.param((qr, H * (nd + rd)), ("lora", "heads")),
        "wkv_a": b.param((d, kr + rd), ("embed", "lora")),
        "kv_norm": b.param((kr,), ("lora",), init="ones"),
        "wkv_b": b.param((kr, H * (nd + vd)), ("lora", "heads")),
        "wo": b.param((H * vd, d), ("heads", "embed")),
    }


def _mla_qkv(p, cfg: ArchConfig, x, positions):
    B, S, _ = x.shape
    H = cfg.n_heads
    nd, rd, vd = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    kr = cfg.mla_kv_rank
    qa = x @ p["wq_a"]
    qa = qa * jax.lax.rsqrt(jnp.mean(qa.astype(jnp.float32) ** 2, -1,
                                     keepdims=True) + 1e-6).astype(qa.dtype) \
        * p["q_norm"]
    q = (qa @ p["wq_b"]).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = x @ p["wkv_a"]
    c_kv, k_rope = ckv[..., :kr], ckv[..., kr:]
    c_kv = c_kv * jax.lax.rsqrt(
        jnp.mean(c_kv.astype(jnp.float32) ** 2, -1, keepdims=True) + 1e-6
    ).astype(c_kv.dtype) * p["kv_norm"]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)   # (B,S,rd) shared
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(p, cfg: ArchConfig, q_nope, q_rope, c_kv, k_rope, mask):
    """Latent attention: expand k_nope/v from the compressed latent."""
    B, T, _ = c_kv.shape
    H = cfg.n_heads
    nd, vd = cfg.mla_nope_dim, cfg.mla_v_dim
    kv = (c_kv @ p["wkv_b"]).reshape(B, T, H, nd + vd)
    k_nope, v = kv[..., :nd], kv[..., nd:]
    s1 = jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
    s2 = jnp.einsum("bshd,btd->bhst", q_rope, k_rope)
    scale = 1.0 / jnp.sqrt(nd + q_rope.shape[-1])
    scores = ((s1 + s2) * scale).astype(jnp.float32)
    if mask.ndim == 2:
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    else:
        scores = jnp.where(mask[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v)
    return out.reshape(B, -1, H * vd) @ p["wo"]


def blockwise_mla(p, cfg: ArchConfig, q_nope, q_rope, c_kv, k_rope,
                  chunk: int = 0):
    """Causal online-softmax MLA — expands k/v from the compressed latent
    chunk-by-chunk, so neither the score matrix nor the expanded KV is ever
    materialized at full length."""
    B, S, H, nd = q_nope.shape
    vd = cfg.mla_v_dim
    chunk = min(chunk or _FLASH_CHUNK["size"], S)
    while S % chunk != 0:
        chunk //= 2
    c = S // chunk
    scale = 1.0 / jnp.sqrt(nd + q_rope.shape[-1])
    cc = jnp.moveaxis(c_kv.reshape(B, c, chunk, -1), 1, 0)
    cr = jnp.moveaxis(k_rope.reshape(B, c, chunk, -1), 1, 0)
    q_pos = jnp.arange(S)

    @jax.checkpoint
    def body(carry, inp):
        m, l, acc = carry
        idx, c_blk, r_blk = inp
        kv = (c_blk @ p["wkv_b"]).reshape(B, chunk, H, nd + vd)
        k_nope, v = kv[..., :nd], kv[..., nd:]
        s = (jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
             + jnp.einsum("bshd,btd->bhst", q_rope, r_blk)).astype(jnp.float32)
        s = s * scale
        k_pos = idx * chunk + jnp.arange(chunk)
        mask = k_pos[None, :] <= q_pos[:, None]
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))          # (B,H,S)
        corr = jnp.exp(m - m_new)
        pr = jnp.exp(s - m_new[..., None])
        l = l * corr + jnp.sum(pr, axis=-1)
        pv = jnp.einsum("bhst,bthd->bshd", pr.astype(v.dtype), v)
        acc = acc * jnp.moveaxis(corr, 2, 1)[..., None].astype(acc.dtype) + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    acc0 = jnp.zeros((B, S, H, vd), c_kv.dtype)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                  (jnp.arange(c), cc, cr))
    out = acc / jnp.maximum(jnp.moveaxis(l, 2, 1), 1e-30)[..., None].astype(acc.dtype)
    return out.reshape(B, S, H * vd) @ p["wo"]


def mla_attention(p, cfg: ArchConfig, x, positions, cache=None,
                  cache_index=None, use_flash: bool = False):
    """Returns (out, new_cache). Cache stores the *compressed* latent:
    (c_kv: (B, S_max, kv_rank), k_rope: (B, S_max, rope_dim))."""
    B, S, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    if cache is None:
        if use_flash and S > 1:
            return blockwise_mla(p, cfg, q_nope, q_rope, c_kv, k_rope), \
                (c_kv, k_rope)
        mask = jnp.tril(jnp.ones((S, S), bool))
        out = _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope, mask)
        return out, (c_kv, k_rope)
    cc, cr = cache
    cc = jax.lax.dynamic_update_slice_in_dim(cc, c_kv.astype(cc.dtype), cache_index, axis=1)
    cr = jax.lax.dynamic_update_slice_in_dim(cr, k_rope.astype(cr.dtype), cache_index, axis=1)
    T = cc.shape[1]
    valid = jnp.arange(T)[None, :] <= positions[:, -1:]       # absolute positions
    mask = jnp.broadcast_to(valid[:, None, :], (B, S, T))
    out = _mla_attend(p, cfg, q_nope, q_rope, cc, cr, mask)
    return out, (cc, cr)


def mla_cache_spec(cfg: ArchConfig, batch: int, seq: int, dtype):
    return (jax.ShapeDtypeStruct((batch, seq, cfg.mla_kv_rank), dtype),
            jax.ShapeDtypeStruct((batch, seq, cfg.mla_rope_dim), dtype))


# ---------------------------------------------------------------------------
# Cross-attention (VLM image layers)
# ---------------------------------------------------------------------------

def xattn_params(b: Builder, cfg: ArchConfig):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "wq": b.param((d, cfg.n_heads * hd), ("embed", "heads")),
        "wk": b.param((d, cfg.n_kv_heads * hd), ("embed", "kv")),
        "wv": b.param((d, cfg.n_kv_heads * hd), ("embed", "kv")),
        "wo": b.param((cfg.n_heads * hd, d), ("heads", "embed")),
        "gate": b.param((1,), (None,), init="zeros"),
    }


def cross_attention(p, cfg: ArchConfig, x, kv_src):
    """x: (B, S, D) text; kv_src: (B, N_img, D) patch embeddings (stub
    frontend). Gated output (zero-init gate, llama-3.2 style)."""
    B, S, _ = x.shape
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    H = cfg.n_heads
    G = H // K
    q = (x @ p["wq"]).reshape(B, S, K, G, hd)
    k = (kv_src @ p["wk"]).reshape(B, -1, K, hd)
    v = (kv_src @ p["wv"]).reshape(B, -1, K, hd)
    mask = jnp.ones((S, k.shape[1]), bool)
    out = _gqa_scores_combine(q, k, v, mask).reshape(B, S, H * hd)
    return jnp.tanh(p["gate"]) * (out @ p["wo"])
