"""Shared layers: RMSNorm, RoPE, SwiGLU MLP, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.module import Builder


def rmsnorm_params(b: Builder, d: int):
    return {"scale": b.param((d,), ("embed",), init="ones")}


def rmsnorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * p["scale"]).astype(dt)


def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd) or (..., S, hd); positions: (..., S)."""
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)                        # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv     # (..., S, hd/2)
    if x.ndim == ang.ndim + 1:                               # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mlp_params(b: Builder, d: int, f: int):
    return {
        "w_gate": b.param((d, f), ("embed", "mlp")),
        "w_up": b.param((d, f), ("embed", "mlp")),
        "w_down": b.param((f, d), ("mlp", "embed")),
    }


def mlp(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def embed_params(b: Builder, vocab: int, d: int):
    return {"table": b.param((vocab, d), ("vocab", "embed"), scale=0.02)}


def embed(p, tokens):
    return p["table"][tokens]


def unembed(p_head, x):
    return x @ p_head


def cross_entropy(logits, labels, vocab: int):
    """Mean CE over tokens; logits in f32 for stability. labels: int ids.

    The gold logit is extracted with a masked reduction (not a gather) so a
    vocab-sharded (TP) logits tensor reduces locally + psum instead of
    all-gathering the full vocab dim.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0), axis=-1)
    return jnp.mean(logz - gold)
