"""State-space / recurrent blocks: Mamba2 (SSD), mLSTM and sLSTM (xLSTM).

Training/prefill uses chunkwise-parallel forms (MXU-friendly); decode uses
O(1)-state recurrent steps — these blocks are why the ssm/hybrid archs
support the ``long_500k`` cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.module import Builder

# ---------------------------------------------------------------------------
# Mamba2 — state-space dual (SSD), chunked. Single B/C group, no short conv
# (simplification documented in DESIGN.md).
# ---------------------------------------------------------------------------


# Hillclimb lever (EXPERIMENTS.md §Perf): the fused in_proj output dim
# (2*d_in + 2N + H) is generally NOT divisible by the TP degree (zamba2:
# 14563 % 16 != 0) -> the divisibility guard replicates the whole 208MB
# parameter and its gradient all-reduces dominate. split_proj=True factors
# it into a TP-shardable (d, 2*d_in) matmul + a small replicated remainder.
_MAMBA_OPTS = {"split_proj": False}


def set_mamba_options(**kw):
    _MAMBA_OPTS.update(kw)


def mamba2_params(b: Builder, cfg: ArchConfig):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = cfg.ssm_heads
    N = cfg.ssm_state
    p = {
        "a_log": b.param((H,), (None,), init="zeros"),
        "skip_d": b.param((H,), (None,), init="ones"),
        "dt_bias": b.param((H,), (None,), init="zeros"),
        "norm": b.param((d_in,), ("mlp",), init="ones"),
        "out_proj": b.param((d_in, d), ("mlp", "embed")),
    }
    if _MAMBA_OPTS["split_proj"]:
        p["in_zx"] = b.param((d, 2 * d_in), ("embed", "mlp"))
        p["in_bcdt"] = b.param((d, 2 * N + H), ("embed", None))
    else:
        p["in_proj"] = b.param((d, 2 * d_in + 2 * N + H), ("embed", "mlp"))
    return p


def _ssd_chunked(xh, dt, a_log, Bm, Cm, chunk: int):
    """SSD over chunks. xh: (B,L,H,P), dt: (B,L,H), Bm/Cm: (B,L,N).

    Returns y: (B,L,H,P) and final state (B,H,N,P).
    """
    Bsz, L, H, P = xh.shape
    N = Bm.shape[-1]
    c = L // chunk
    A = -jnp.exp(a_log.astype(jnp.float32))                  # (H,) negative
    dA = dt * A                                              # (B,L,H)
    xk = (xh * dt[..., None]).reshape(Bsz, c, chunk, H, P)
    dAk = dA.reshape(Bsz, c, chunk, H)
    Bk = Bm.reshape(Bsz, c, chunk, N)
    Ck = Cm.reshape(Bsz, c, chunk, N)

    cs = jnp.cumsum(dAk, axis=2)                             # (B,c,k,H)
    # intra-chunk: M[s,t] = C_s·B_t · exp(cs_s - cs_t) for t <= s
    decay = jnp.exp(cs[:, :, :, None, :] - cs[:, :, None, :, :])  # (B,c,k,k,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    G = jnp.einsum("bcsn,bctn->bcst", Ck, Bk)                # (B,c,k,k)
    M = jnp.where(tri[None, None, :, :, None], G[..., None] * decay, 0.0)
    y_intra = jnp.einsum("bcsth,bcthp->bcshp", M, xk)

    # per-chunk input state: S_c = Σ_t exp(cs_last - cs_t) B_t ⊗ x_t
    last = cs[:, :, -1:, :]                                  # (B,c,1,H)
    w = jnp.exp(last - cs)                                   # (B,c,k,H)
    S_c = jnp.einsum("bctn,bcth,bcthp->bchnp", Bk, w, xk)    # (B,c,H,N,P)
    total = jnp.exp(last[:, :, 0, :])                        # (B,c,H)

    def scan_fn(state, inp):
        S_chunk, tot = inp                                   # (B,H,N,P), (B,H)
        out_state = state
        state = state * tot[:, :, None, None] + S_chunk
        return state, out_state

    state0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    final_state, prev_states = lax.scan(
        scan_fn, state0,
        (jnp.moveaxis(S_c, 1, 0).astype(jnp.float32),
         jnp.moveaxis(total, 1, 0).astype(jnp.float32)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (B,c,H,N,P)

    y_inter = jnp.einsum("bcsn,bchnp,bcsh->bcshp", Ck, prev_states,
                         jnp.exp(cs))
    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    return y, final_state


def mamba2_block(p, cfg: ArchConfig, x, state=None):
    """x: (B,S,D). state: (B,H,N,P) for decode (S==1) else None.

    Returns (out, new_state).
    """
    B, S, D = x.shape
    d_in = cfg.ssm_expand * D
    H, N = cfg.ssm_heads, cfg.ssm_state
    P = d_in // H
    if "in_zx" in p:
        proj_zx = x @ p["in_zx"]
        proj_r = x @ p["in_bcdt"]
        z, xi = proj_zx[..., :d_in], proj_zx[..., d_in:]
        Bm, Cm, dt = jnp.split(proj_r, [N, 2 * N], axis=-1)
    else:
        proj = x @ p["in_proj"]
        z, xi, Bm, Cm, dt = jnp.split(
            proj, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    xh = xi.reshape(B, S, H, P)

    if state is None:
        y, new_state = _ssd_chunked(xh, dt, p["a_log"], Bm, Cm,
                                    min(cfg.ssm_chunk, S))
        new_state = new_state.astype(xh.dtype)
    else:
        # single-step recurrence
        A = -jnp.exp(p["a_log"].astype(jnp.float32))
        dA = jnp.exp(dt[:, 0] * A)                           # (B,H)
        dBx = jnp.einsum("bn,bh,bhp->bhnp", Bm[:, 0], dt[:, 0], xh[:, 0])
        new_state = (state * dA[:, :, None, None] + dBx).astype(state.dtype)
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0], new_state)[:, None]
    y = y + xh * p["skip_d"][None, None, :, None]
    y = y.reshape(B, S, d_in) * jax.nn.silu(z)
    y32 = y.astype(jnp.float32)
    y = (y32 * lax.rsqrt(jnp.mean(y32**2, -1, keepdims=True) + 1e-6)
         ).astype(x.dtype) * p["norm"]
    return y @ p["out_proj"], new_state


def mamba2_state_spec(cfg: ArchConfig, batch: int, dtype):
    d_in = cfg.ssm_expand * cfg.d_model
    P = d_in // cfg.ssm_heads
    return jax.ShapeDtypeStruct((batch, cfg.ssm_heads, cfg.ssm_state, P),
                                dtype)


# ---------------------------------------------------------------------------
# mLSTM — matrix-memory LSTM (xLSTM), stabilized parallel + recurrent forms.
# ---------------------------------------------------------------------------


def mlstm_params(b: Builder, cfg: ArchConfig):
    d = cfg.d_model
    pd = int(cfg.lstm_proj_factor * d)
    return {
        "w_up": b.param((d, 2 * pd), ("embed", "mlp")),
        "wq": b.param((pd, pd), ("mlp", "heads")),
        "wk": b.param((pd, pd), ("mlp", "heads")),
        "wv": b.param((pd, pd), ("mlp", "heads")),
        "w_if": b.param((pd, 2 * cfg.n_heads), ("mlp", None)),
        "norm": b.param((pd,), ("mlp",), init="ones"),
        "w_down": b.param((pd, d), ("mlp", "embed")),
    }


def mlstm_block(p, cfg: ArchConfig, x, state=None):
    """x: (B,S,D). state = (C: (B,H,P,P'), n: (B,H,P), m: (B,H)) for decode."""
    B, S, D = x.shape
    pd = int(cfg.lstm_proj_factor * D)
    H = cfg.n_heads
    P = pd // H
    up = x @ p["w_up"]
    xi, z = up[..., :pd], up[..., pd:]
    q = (xi @ p["wq"]).reshape(B, S, H, P)
    k = (xi @ p["wk"]).reshape(B, S, H, P) / jnp.sqrt(P)
    v = (xi @ p["wv"]).reshape(B, S, H, P)
    gates = (xi @ p["w_if"]).astype(jnp.float32)             # (B,S,2H)
    i_raw, f_raw = gates[..., :H], gates[..., H:]
    log_f = jax.nn.log_sigmoid(f_raw)                        # (B,S,H)

    if state is None:
        F = jnp.cumsum(log_f, axis=1)                        # (B,S,H)
        Dmat = F[:, :, None, :] - F[:, None, :, :] + i_raw[:, None, :, :]
        tri = jnp.tril(jnp.ones((S, S), bool))
        Dmat = jnp.where(tri[None, :, :, None], Dmat, -jnp.inf)
        m = jnp.max(Dmat, axis=2, keepdims=True)             # (B,S,1,H)
        m = jnp.maximum(m, -1e30)
        W = jnp.exp(Dmat - m)                                # (B,S,T,H)
        scores = jnp.einsum("bshp,bthp->bsth", q, k) * W
        denom = jnp.maximum(jnp.abs(jnp.sum(scores, axis=2)),
                            jnp.exp(-m[:, :, 0, :]))         # (B,S,H)
        h = jnp.einsum("bsth,bthp->bshp", scores, v) / denom[..., None]
        # final recurrent state for handoff to decode
        mT = F[:, -1:, :] - F + i_raw                        # (B,S,H) decay-to-end
        m_last = jnp.maximum(jnp.max(mT, axis=1), -1e30)     # (B,H)
        wT = jnp.exp(mT - m_last[:, None, :])
        C_last = jnp.einsum("bsh,bshp,bshq->bhpq", wT, v, k).astype(v.dtype)
        n_last = jnp.einsum("bsh,bshp->bhp", wT, k).astype(v.dtype)
        new_state = (C_last, n_last, m_last.astype(jnp.float32))
    else:
        C, n, m_prev = state
        i_t, lf_t = i_raw[:, 0], log_f[:, 0]                 # (B,H)
        m_new = jnp.maximum(lf_t + m_prev, i_t)
        f_s = jnp.exp(lf_t + m_prev - m_new)[:, :, None]
        i_s = jnp.exp(i_t - m_new)[:, :, None]
        C = (C * f_s[..., None] + i_s[..., None] * jnp.einsum(
            "bhp,bhq->bhpq", v[:, 0], k[:, 0])).astype(C.dtype)
        n = (n * f_s + i_s * k[:, 0]).astype(n.dtype)
        num = jnp.einsum("bhpq,bhq->bhp", C, q[:, 0])
        den = jnp.maximum(jnp.abs(jnp.sum(n * q[:, 0], -1)),
                          jnp.exp(-m_new))[..., None]
        h = (num / den)[:, None]                             # (B,1,H,P)
        new_state = (C, n, m_new)

    h = h.reshape(B, S, pd).astype(x.dtype)
    h32 = h.astype(jnp.float32)
    h = (h32 * lax.rsqrt(jnp.mean(h32**2, -1, keepdims=True) + 1e-6)
         ).astype(x.dtype) * p["norm"]
    out = (h * jax.nn.silu(z)) @ p["w_down"]
    return out, new_state


def mlstm_state_spec(cfg: ArchConfig, batch: int, dtype):
    pd = int(cfg.lstm_proj_factor * cfg.d_model)
    H = cfg.n_heads
    P = pd // H
    return (jax.ShapeDtypeStruct((batch, H, P, P), dtype),
            jax.ShapeDtypeStruct((batch, H, P), dtype),
            jax.ShapeDtypeStruct((batch, H), jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM — scalar-memory LSTM with exponential gating (recurrent only).
# ---------------------------------------------------------------------------


def slstm_params(b: Builder, cfg: ArchConfig):
    d = cfg.d_model
    pd = int(cfg.lstm_proj_factor * d)
    H = cfg.n_heads
    hd = pd // H
    return {
        "w_up": b.param((d, 2 * pd), ("embed", "mlp")),
        "w_in": b.param((pd, 4 * pd), ("mlp", None)),       # z,i,f,o pre-acts
        "r": b.param((4, H, hd, hd), (None, "heads", None, None),
                     scale=0.5 / hd**0.5),                  # recurrent, per head
        "norm": b.param((pd,), ("mlp",), init="ones"),
        "w_down": b.param((pd, d), ("mlp", "embed")),
    }


def _slstm_step(p, cfg, pre, carry):
    """One recurrence step. pre: (B, 4*pd) input pre-activations."""
    c, n, m, h = carry                                       # each (B, pd)/(B,pd)
    B = pre.shape[0]
    pd = c.shape[-1]
    H = cfg.n_heads
    hd = pd // H
    hh = h.reshape(B, H, hd)
    rec = jnp.einsum("bhd,ghde->bghe", hh, p["r"]).reshape(B, 4, pd)
    z_r, i_r, f_r, o_r = [jnp.squeeze(t, 1) for t in jnp.split(
        pre.reshape(B, 4, pd) + rec, 4, axis=1)]
    z = jnp.tanh(z_r)
    o = jax.nn.sigmoid(o_r)
    lf = jax.nn.log_sigmoid(f_r.astype(jnp.float32))
    m_new = jnp.maximum(lf + m, i_r.astype(jnp.float32))
    i_s = jnp.exp(i_r - m_new)
    f_s = jnp.exp(lf + m - m_new)
    c = f_s * c + i_s * z
    n = f_s * n + i_s
    h = o * (c / jnp.maximum(n, 1.0))
    return (c, n, m_new, h)


def slstm_block(p, cfg: ArchConfig, x, state=None):
    """x: (B,S,D). state = (c,n,m,h) each (B,pd) for decode."""
    B, S, D = x.shape
    pd = int(cfg.lstm_proj_factor * D)
    up = x @ p["w_up"]
    xi, z_gate = up[..., :pd], up[..., pd:]
    pre = xi @ p["w_in"]                                     # (B,S,4pd)

    if state is None:
        carry0 = (jnp.zeros((B, pd), jnp.float32), jnp.zeros((B, pd), jnp.float32),
                  jnp.full((B, pd), -1e30, jnp.float32), jnp.zeros((B, pd), jnp.float32))

        def step(carry, pre_t):
            new = _slstm_step(p, cfg, pre_t, carry)
            return new, new[3]

        new_state, hs = lax.scan(step, carry0, jnp.moveaxis(pre, 1, 0))
        h = jnp.moveaxis(hs, 0, 1)                           # (B,S,pd)
    else:
        new_state = _slstm_step(p, cfg, pre[:, 0], state)
        h = new_state[3][:, None]
    h = h.astype(x.dtype)
    h32 = h.astype(jnp.float32)
    h = (h32 * lax.rsqrt(jnp.mean(h32**2, -1, keepdims=True) + 1e-6)
         ).astype(x.dtype) * p["norm"]
    out = (h * jax.nn.silu(z_gate)) @ p["w_down"]
    return out, new_state


def slstm_state_spec(cfg: ArchConfig, batch: int, dtype):
    pd = int(cfg.lstm_proj_factor * cfg.d_model)
    f32 = jnp.float32
    return tuple(jax.ShapeDtypeStruct((batch, pd), f32) for _ in range(4))
