"""Mixture-of-Experts MLP with top-k routing — two dispatch formulations.

``gshard`` (default, SPMD/TPU-native): grouped one-hot dispatch built from
cumsums — einsums only, no scatter/gather, so XLA SPMD reshards the
token→expert hop as an all-to-all instead of replicating token tensors.
Capacity is per-group (GShard semantics).

``sort``: tokens argsorted by expert into an (E, capacity, D) buffer
(modern grouped-GEMM style); global capacity; scatter-based — better on
architectures with fast gather, kept as reference/CPU path.

Both drop overflow tokens (capacity factor) and return the Switch
load-balancing auxiliary loss; ``no_drop=True`` sizes buffers so nothing
drops (used for decode and for cross-impl equivalence tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import mlp, mlp_params
from repro.models.module import Builder
from repro.models.sharding_ctx import constrain

_GROUP_SIZE = 4096

# Hillclimb lever: dispatch/combine tensors in bf16 instead of f32
# (halves the largest MoE transients; gate weights stay f32 until applied).
_MOE_OPTS = {"bf16_dispatch": False}


def set_moe_options(**kw):
    _MOE_OPTS.update(kw)


def moe_params(b: Builder, cfg: ArchConfig):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": b.param((d, E), ("embed", None)),
        "w_gate": b.param((E, d, f), ("expert", "embed", "mlp")),
        "w_up": b.param((E, d, f), ("expert", "embed", "mlp")),
        "w_down": b.param((E, f, d), ("expert", "mlp", "embed")),
    }
    if cfg.shared_expert:
        p["shared"] = mlp_params(b, d, f)
    return p


def moe_mlp(p, cfg: ArchConfig, x, no_drop: bool = False,
            impl: str = "gshard"):
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar)."""
    if impl == "gshard":
        return moe_mlp_gshard(p, cfg, x, no_drop=no_drop)
    return moe_mlp_sort(p, cfg, x, no_drop=no_drop)


def moe_mlp_gshard(p, cfg: ArchConfig, x, no_drop: bool = False):
    """GShard einsum dispatch. x: (B,S,D) -> (out, aux)."""
    B, S, D = x.shape
    T = B * S
    E = cfg.n_experts
    k = cfg.experts_per_token
    Sg = min(_GROUP_SIZE, T)
    while T % Sg != 0:
        Sg //= 2
    G = T // Sg
    cap = Sg * k if no_drop else max(
        1, int(Sg * k / E * cfg.capacity_factor))
    xg = x.reshape(G, Sg, D)
    xg = constrain(xg, "dp", None, None)

    logits = (xg @ p["router"]).astype(jnp.float32)          # (G,Sg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(logits, k)                # (G,Sg,k)
    weights = jax.nn.softmax(gate_vals, axis=-1)

    counts_used = jnp.zeros((G, E), jnp.float32)
    comb_dtype = jnp.bfloat16 if _MOE_OPTS["bf16_dispatch"] else jnp.float32
    dispatch = jnp.zeros((G, Sg, E, cap), jnp.bool_)
    combine = jnp.zeros((G, Sg, E, cap), comb_dtype)
    for j in range(k):
        oh = jax.nn.one_hot(sel[..., j], E, dtype=jnp.float32)   # (G,Sg,E)
        cum = jnp.cumsum(oh, axis=1) - oh                        # exclusive
        pos_e = cum + counts_used[:, None, :]
        pos = jnp.sum(oh * pos_e, axis=-1).astype(jnp.int32)     # (G,Sg)
        keep = pos < cap
        d_j = (oh.astype(bool)[..., None]
               & jax.nn.one_hot(pos, cap, dtype=jnp.bool_)[:, :, None, :]
               & keep[..., None, None])
        dispatch = dispatch | d_j
        combine = combine + (d_j * weights[..., j][..., None, None]
                             ).astype(comb_dtype)
        counts_used = counts_used + jnp.sum(oh, axis=1)

    # Switch aux loss over all tokens
    frac = jnp.mean(jnp.sum(dispatch, axis=3).astype(jnp.float32),
                    axis=(0, 1))                             # (E,) usage
    aux = E * jnp.sum(frac / k * jnp.mean(probs, axis=(0, 1)))

    dm = dispatch.astype(x.dtype)
    buf = jnp.einsum("gsec,gsd->gecd", dm, xg)               # token→expert hop
    buf = constrain(buf, "dp", "model", None, None)          # EP all-to-all
    h = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = constrain(y, "dp", "model", None, None)
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), y)
    out = constrain(out, "dp", None, None)
    out = out.reshape(B, S, D)
    if cfg.shared_expert:
        out = out + mlp(p["shared"], x)
    return out, aux


def moe_mlp_sort(p, cfg: ArchConfig, x, no_drop: bool = False):
    """Sort/scatter dispatch (global capacity). x: (B,S,D) -> (out, aux)."""
    B, S, D = x.shape
    T = B * S
    E = cfg.n_experts
    k = cfg.experts_per_token
    cap = T * k if no_drop else max(1, int(T * k / E * cfg.capacity_factor))
    xf = x.reshape(T, D)

    logits = (xf @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(logits, k)                # (T, k)
    weights = jax.nn.softmax(gate_vals, axis=-1).astype(x.dtype)

    # load-balance aux loss (Switch): E * Σ_e frac_tokens_e * mean_prob_e
    counts = jnp.zeros((E,), jnp.float32).at[sel.reshape(-1)].add(1.0)
    aux = E * jnp.sum((counts / (T * k)) * jnp.mean(probs, axis=0))

    # sort token-expert assignments by expert
    ex = sel.reshape(-1)                                     # (T*k,)
    wt = weights.reshape(-1)
    tok = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(ex)
    ex_s, tok_s, wt_s = ex[order], tok[order], wt[order]
    pos = jnp.arange(T * k) - jnp.searchsorted(ex_s, ex_s, side="left")
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)                         # overflow -> slot `cap`

    buf = jnp.zeros((E, cap + 1, D), x.dtype)
    buf = buf.at[ex_s, slot].set(xf[tok_s])[:, :cap]         # (E, cap, D)

    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])           # (E, cap, D)

    gathered = y[ex_s, jnp.minimum(pos, cap - 1)]            # (T*k, D)
    contrib = gathered * (wt_s * keep.astype(x.dtype))[:, None]
    out = jnp.zeros((T, D), x.dtype).at[tok_s].add(contrib)

    if cfg.shared_expert:
        out = out + mlp(p["shared"], xf)
    return out.reshape(B, S, D), aux
