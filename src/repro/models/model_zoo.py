"""Composable decoder stack covering all 10 assigned architectures.

A model is ``n_superblocks`` scanned repetitions of a *superblock* (the
arch's ``block_pattern``), plus optional tail blocks and an optional shared
transformer block invoked once per superblock (Zamba2). Scan-over-layers
keeps the HLO small (one superblock body compiled once) — essential for the
512-device dry-run and for XLA's latency-hiding scheduler.

Modes:
  · ``forward``      — teacher-forced training forward (no caches kept)
  · ``prefill``      — forward + KV/state caches (padded to ``cache_len``)
  · ``decode_step``  — one token with functional cache update
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import ssm
from repro.models.layers import (
    cross_entropy,
    embed,
    embed_params,
    mlp,
    mlp_params,
    rmsnorm,
    rmsnorm_params,
)
from repro.models.module import Builder
from repro.models.moe import moe_mlp, moe_params

from repro.models.sharding_ctx import (
    constrain_acts as _constrain_acts,
    constrain_logits as _constrain_logits,
    set_activation_sharding,
)

# ---------------------------------------------------------------------------
# Block level
# ---------------------------------------------------------------------------

def _attn_params(b: Builder, cfg: ArchConfig):
    return attn.mla_params(b, cfg) if cfg.attn_type == "mla" \
        else attn.gqa_params(b, cfg)


def _attn_apply(p, cfg, x, positions, cache, cache_index, use_flash):
    fn = attn.mla_attention if cfg.attn_type == "mla" else attn.gqa_attention
    return fn(p, cfg, x, positions, cache=cache, cache_index=cache_index,
              use_flash=use_flash)


def block_params(b: Builder, cfg: ArchConfig, kind: str):
    d = cfg.d_model
    if kind == "attn":
        return {"n1": rmsnorm_params(b, d), "attn": _attn_params(b, cfg),
                "n2": rmsnorm_params(b, d), "mlp": mlp_params(b, d, cfg.d_ff)}
    if kind == "moe":
        return {"n1": rmsnorm_params(b, d), "attn": _attn_params(b, cfg),
                "n2": rmsnorm_params(b, d), "moe": moe_params(b, cfg)}
    if kind == "xattn":
        return {"n1": rmsnorm_params(b, d), "xattn": attn.xattn_params(b, cfg),
                "n2": rmsnorm_params(b, d), "mlp": mlp_params(b, d, cfg.d_ff)}
    if kind == "mamba2":
        return {"n1": rmsnorm_params(b, d), "mamba": ssm.mamba2_params(b, cfg)}
    if kind == "mlstm":
        return {"n1": rmsnorm_params(b, d), "lstm": ssm.mlstm_params(b, cfg)}
    if kind == "slstm":
        return {"n1": rmsnorm_params(b, d), "lstm": ssm.slstm_params(b, cfg)}
    raise ValueError(kind)


def block_apply(p, cfg: ArchConfig, kind: str, x, positions, cache,
                cache_index, img, use_flash):
    """Returns (x, new_cache, aux_loss)."""
    eps = cfg.norm_eps
    if kind in ("attn", "moe"):
        h, new_cache = _attn_apply(p["attn"], cfg, rmsnorm(p["n1"], x, eps),
                                   positions, cache, cache_index, use_flash)
        x = x + h.astype(x.dtype)
        if kind == "attn":
            x = x + mlp(p["mlp"], rmsnorm(p["n2"], x, eps)).astype(x.dtype)
            return x, new_cache, jnp.float32(0.0)
        h, aux = moe_mlp(p["moe"], cfg, rmsnorm(p["n2"], x, eps),
                         no_drop=(x.shape[1] == 1))
        return x + h.astype(x.dtype), new_cache, aux
    if kind == "xattn":
        x = x + attn.cross_attention(p["xattn"], cfg,
                                     rmsnorm(p["n1"], x, eps), img).astype(x.dtype)
        x = x + mlp(p["mlp"], rmsnorm(p["n2"], x, eps)).astype(x.dtype)
        return x, (), jnp.float32(0.0)
    if kind == "mamba2":
        h, new_state = ssm.mamba2_block(p["mamba"], cfg,
                                        rmsnorm(p["n1"], x, eps), cache)
        return x + h.astype(x.dtype), new_state, jnp.float32(0.0)
    if kind == "mlstm":
        h, new_state = ssm.mlstm_block(p["lstm"], cfg,
                                       rmsnorm(p["n1"], x, eps), cache)
        return x + h.astype(x.dtype), new_state, jnp.float32(0.0)
    if kind == "slstm":
        h, new_state = ssm.slstm_block(p["lstm"], cfg,
                                       rmsnorm(p["n1"], x, eps), cache)
        return x + h.astype(x.dtype), new_state, jnp.float32(0.0)
    raise ValueError(kind)


def block_cache_spec(cfg: ArchConfig, kind: str, batch: int, cache_len: int,
                     dtype):
    if kind in ("attn", "moe"):
        return attn.mla_cache_spec(cfg, batch, cache_len, dtype) \
            if cfg.attn_type == "mla" \
            else attn.gqa_cache_spec(cfg, batch, cache_len, dtype)
    if kind == "xattn":
        return ()
    if kind == "mamba2":
        return ssm.mamba2_state_spec(cfg, batch, dtype)
    if kind == "mlstm":
        return ssm.mlstm_state_spec(cfg, batch, dtype)
    if kind == "slstm":
        return ssm.slstm_state_spec(cfg, batch, dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Superblock / stack
# ---------------------------------------------------------------------------

def superblock_params(b: Builder, cfg: ArchConfig):
    p = {f"b{i}": block_params(b, cfg, kind)
         for i, kind in enumerate(cfg.block_pattern)}
    return p


def shared_block_params(b: Builder, cfg: ArchConfig):
    """Zamba2-style shared attention+MLP block (one copy, many invocations)."""
    d = cfg.d_model
    return {"n1": rmsnorm_params(b, d), "attn": attn.gqa_params(b, cfg),
            "n2": rmsnorm_params(b, d), "mlp": mlp_params(b, d, cfg.d_ff)}


def superblock_apply(p, shared_p, cfg: ArchConfig, x, positions, caches,
                     shared_cache, cache_index, img, use_flash):
    """Returns (x, new_caches, new_shared_cache, aux)."""
    aux = jnp.float32(0.0)
    new_caches = []
    for i, kind in enumerate(cfg.block_pattern):
        c = caches[i] if caches is not None else None
        x, nc, a = block_apply(p[f"b{i}"], cfg, kind, x, positions, c,
                               cache_index, img, use_flash)
        new_caches.append(nc)
        aux = aux + a
    new_shared = shared_cache
    if shared_p is not None:
        h, new_shared = attn.gqa_attention(
            shared_p["attn"], cfg, rmsnorm(shared_p["n1"], x, cfg.norm_eps),
            positions, cache=shared_cache, cache_index=cache_index,
            use_flash=use_flash)
        x = x + h.astype(x.dtype)
        x = x + mlp(shared_p["mlp"],
                    rmsnorm(shared_p["n2"], x, cfg.norm_eps)).astype(x.dtype)
    return x, tuple(new_caches), new_shared, aux


class Model:
    """Functional model wrapper for one architecture config."""

    def __init__(self, cfg: ArchConfig, unroll_layers: bool = False):
        self.cfg = cfg
        # unroll_layers: replace the layer scan with a Python loop. Used by
        # the dry-run's cost compiles — XLA cost_analysis counts loop bodies
        # once (not x trip count), so FLOP/byte accounting needs an unrolled
        # program. Production path keeps the scan (small HLO, fast compile).
        self.unroll_layers = unroll_layers

    # -- parameters ---------------------------------------------------------

    def _build(self, b: Builder):
        cfg = self.cfg
        p: Dict[str, Any] = {}
        p["embed"] = embed_params(b, cfg.vocab_size, cfg.d_model)
        if cfg.n_codebooks > 1:
            p["codebook_embeds"] = b.param(
                (cfg.n_codebooks - 1, cfg.vocab_size, cfg.d_model),
                (None, "vocab", "embed"), scale=0.02)
        p["blocks"] = b.vmapped(
            lambda bb: superblock_params(bb, cfg), cfg.resolved_superblocks)
        if cfg.tail_blocks:
            p["tail"] = [block_params(b, cfg, k) for k in cfg.tail_blocks]
        if cfg.shared_block_every:
            p["shared"] = shared_block_params(b, cfg)
        p["final_norm"] = rmsnorm_params(b, cfg.d_model)
        if cfg.n_codebooks > 1:
            p["heads"] = b.param((cfg.n_codebooks, cfg.d_model, cfg.vocab_size),
                                 (None, "embed", "vocab"))
        elif not cfg.tie_embeddings:
            p["head"] = b.param((cfg.d_model, cfg.vocab_size),
                                ("embed", "vocab"))
        return p

    def init(self, key):
        return self._build(Builder("init", key))

    def abstract_params(self):
        return self._build(Builder("shape"))

    def param_axes(self):
        return self._build(Builder("axes"))

    # -- caches -------------------------------------------------------------

    def cache_spec(self, batch: int, cache_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        n_sb = cfg.resolved_superblocks

        def stack(spec):
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n_sb,) + s.shape, s.dtype),
                spec)

        sb = tuple(block_cache_spec(cfg, k, batch, cache_len, dtype)
                   for k in cfg.block_pattern)
        spec: Dict[str, Any] = {"blocks": stack(sb)}
        if cfg.tail_blocks:
            spec["tail"] = tuple(
                block_cache_spec(cfg, k, batch, cache_len, dtype)
                for k in cfg.tail_blocks)
        if cfg.shared_block_every:
            spec["shared"] = stack(
                attn.gqa_cache_spec(cfg, batch, cache_len, dtype))
        return spec

    def init_cache(self, batch: int, cache_len: int, dtype=jnp.bfloat16):
        """Zero caches — except LSTM stabilizer states, which start at -inf
        (empty history) so the first recurrent step matches the parallel
        form exactly."""
        cfg = self.cfg
        spec = self.cache_spec(batch, cache_len, dtype)

        def init_block(kind, c):
            zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), c)
            if kind == "mlstm":
                C, n, m = zeros
                return (C, n, jnp.full(m.shape, -1e30, m.dtype))
            if kind == "slstm":
                c_, n_, m_, h_ = zeros
                return (c_, n_, jnp.full(m_.shape, -1e30, m_.dtype), h_)
            return zeros

        out = {"blocks": tuple(
            init_block(k, spec["blocks"][i])
            for i, k in enumerate(cfg.block_pattern))}
        if cfg.tail_blocks:
            out["tail"] = tuple(
                init_block(k, spec["tail"][i])
                for i, k in enumerate(cfg.tail_blocks))
        if cfg.shared_block_every:
            out["shared"] = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                         spec["shared"])
        return out

    # -- embedding / head ----------------------------------------------------

    @staticmethod
    def _cast_params(params, act_dtype):
        """Compute copy of params in the activation dtype (mixed precision);
        master weights stay fp32 in the optimizer."""
        return jax.tree.map(
            lambda p: p.astype(act_dtype)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)

    def _embed_tokens(self, params, tokens, act_dtype):
        cfg = self.cfg
        if cfg.n_codebooks > 1:
            # tokens: (B, S, n_codebooks); sum codebook embeddings (stub
            # EnCodec frontend per assignment)
            x = embed(params["embed"], tokens[..., 0])
            for cb in range(cfg.n_codebooks - 1):
                x = x + params["codebook_embeds"][cb][tokens[..., cb + 1]]
            return x.astype(act_dtype)
        return embed(params["embed"], tokens).astype(act_dtype)

    def _logits(self, params, x):
        cfg = self.cfg
        x = x.astype(jnp.float32)
        if cfg.n_codebooks > 1:
            out = jnp.einsum("bsd,cdv->bscv", x,
                             params["heads"].astype(jnp.float32))
        elif cfg.tie_embeddings:
            out = x @ params["embed"]["table"].astype(jnp.float32).T
        else:
            out = x @ params["head"].astype(jnp.float32)
        return _constrain_logits(out)

    # -- core stack ----------------------------------------------------------

    def _stack(self, params, x, positions, caches, cache_index, img,
               use_flash, want_cache, remat):
        cfg = self.cfg
        shared_p = params.get("shared")
        has_shared = bool(cfg.shared_block_every)

        def scan_fn(carry, xs):
            x, aux = carry
            x = _constrain_acts(x)
            if caches is None:
                blk_p, sb_cache, sh_cache = xs, None, None
            elif has_shared:
                blk_p, (sb_cache, sh_cache) = xs
            else:
                blk_p, sb_cache = xs
                sh_cache = None
            x, new_sb, new_sh, a = superblock_apply(
                blk_p, shared_p, cfg, x, positions, sb_cache, sh_cache,
                cache_index, img, use_flash)
            if want_cache:
                out = (new_sb, new_sh) if has_shared else new_sb
            else:
                out = None
            return (x, aux + a), out

        body = jax.checkpoint(scan_fn) if remat else scan_fn
        if caches is None:
            xs = params["blocks"]
        elif has_shared:
            xs = (params["blocks"], (caches["blocks"], caches["shared"]))
        else:
            xs = (params["blocks"], caches["blocks"])

        if self.unroll_layers:
            n_sb = cfg.resolved_superblocks
            carry = (x, jnp.float32(0.0))
            outs = []
            for i in range(n_sb):
                xs_i = jax.tree.map(lambda a: a[i], xs)
                carry, out_i = body(carry, xs_i)
                outs.append(out_i)
            x, aux = carry
            scanned_caches = None if not want_cache else \
                jax.tree.map(lambda *ys: jnp.stack(ys), *outs)
        else:
            (x, aux), scanned_caches = lax.scan(body, (x, jnp.float32(0.0)),
                                                xs)

        new_tail = []
        if cfg.tail_blocks:
            for i, kind in enumerate(cfg.tail_blocks):
                c = None if caches is None else caches["tail"][i]
                x, nc, a = block_apply(params["tail"][i], cfg, kind, x,
                                       positions, c, cache_index, img,
                                       use_flash)
                new_tail.append(nc)
                aux = aux + a

        cache_out = None
        if want_cache:
            if has_shared:
                cache_out = {"blocks": scanned_caches[0],
                             "shared": scanned_caches[1]}
            else:
                cache_out = {"blocks": scanned_caches}
            if cfg.tail_blocks:
                cache_out["tail"] = tuple(new_tail)
        return x, aux, cache_out

    # -- public entry points --------------------------------------------------

    def forward(self, params, tokens, img=None, act_dtype=jnp.float32,
                use_flash: bool = False, remat: bool = False):
        """Training forward. Returns (logits, final_hidden, aux_loss)."""
        B, S = tokens.shape[0], tokens.shape[1]
        params = self._cast_params(params, act_dtype)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = self._embed_tokens(params, tokens, act_dtype)
        x, aux, _ = self._stack(params, x, positions, None, None, img,
                                use_flash, want_cache=False, remat=remat)
        x = rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        return self._logits(params, x), x, aux

    def prefill(self, params, tokens, img=None, cache_len: Optional[int] = None,
                act_dtype=jnp.bfloat16, use_flash: bool = False):
        """Prefill forward; returns (logits, cache) with caches filled.

        For simplicity the cache is built at ``cache_len == S`` via the
        fresh-cache path of each block (paddable by the caller).
        """
        B, S = tokens.shape[0], tokens.shape[1]
        params = self._cast_params(params, act_dtype)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = self._embed_tokens(params, tokens, act_dtype)
        x, aux, cache = self._stack(params, x, positions, None, None, img,
                                    use_flash, want_cache=True, remat=False)
        x = rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        return self._logits(params, x[:, -1:]), cache

    def decode_step(self, params, tokens, cache, index, img=None,
                    act_dtype=jnp.bfloat16):
        """One decode step. tokens: (B, 1) (or (B,1,n_codebooks));
        index: scalar int32 — absolute position / cache write offset.
        Returns (logits, new_cache)."""
        B = tokens.shape[0]
        params = self._cast_params(params, act_dtype)
        positions = jnp.broadcast_to(index[None, None], (B, 1)) \
            if jnp.ndim(index) == 0 else index
        x = self._embed_tokens(params, tokens, act_dtype)
        idx = index if jnp.ndim(index) == 0 else index[0, 0]
        x, aux, cache = self._stack(params, x, positions, cache, idx, img,
                                    False, want_cache=True, remat=False)
        x = rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        return self._logits(params, x), cache

    # -- loss -----------------------------------------------------------------

    def loss(self, params, batch, act_dtype=jnp.float32,
             use_flash: bool = False, remat: bool = False,
             gw_align: bool = False, gw_key=None):
        """Causal LM loss (+ optional GW alignment auxiliary loss)."""
        cfg = self.cfg
        logits, hidden, aux = self.forward(
            params, batch["tokens"], img=batch.get("image_embeds"),
            act_dtype=act_dtype, use_flash=use_flash, remat=remat)
        if cfg.n_codebooks > 1:
            ce = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        else:
            ce = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        loss = ce + 0.01 * aux
        if gw_align:
            from repro.core.align import gw_alignment_loss
            # align final-layer geometry to embedding geometry (structure
            # preservation — the paper's technique as a training feature)
            emb = self._embed_tokens(params, batch["tokens"], act_dtype)
            loss = loss + 0.1 * gw_alignment_loss(gw_key, hidden, emb)
        return loss, {"ce": ce, "aux": aux}


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
