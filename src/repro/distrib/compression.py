"""Gradient compression for cross-pod data parallelism.

At multi-pod scale the DCN all-reduce dominates; int8 block-quantized
gradient all-reduce cuts wire bytes 4x vs f32 (2x vs bf16) at bounded
relative error (tested). Used under ``shard_map`` where the DP reduction is
explicit; under plain jit-SPMD the reduction is XLA-implicit, so the
trainer exposes ``--grad-compression`` which switches the DP axis handling
to the shard_map path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

_BLOCK = 256


def quantize_int8(x):
    """Block-wise symmetric int8 quantization. Returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compressed_psum(x, axis_name: str):
    """int8-quantize -> all-gather(q, scales) -> local dequant-sum, inside
    shard_map. Wire payload is int8 + one f32 scale per 256-block: ~4x less
    traffic than an f32 ring all-reduce. Per-shard error is bounded by its
    own block max / 127 (each shard's contribution uses its own scale).
    """
    q, scale = quantize_int8(x)
    q_all = jax.lax.all_gather(q, axis_name)          # (n, blocks, 256)
    s_all = jax.lax.all_gather(scale, axis_name)      # (n, blocks, 1)
    total = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0)
    m = 1
    for d in x.shape:
        m *= d
    return total.reshape(-1)[:m].reshape(x.shape)


def dp_allreduce_grads(grads, axis_name: str, compress: bool = False):
    """Mean-reduce gradients across a data-parallel shard_map axis."""
    n = jax.lax.psum(1, axis_name)
    if compress:
        return jax.tree.map(lambda g: compressed_psum(g, axis_name) / n, grads)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_name) / n, grads)
