"""Logical-axis → mesh-axis sharding rules (DP / FSDP / TP / SP / EP).

Every parameter carries a tuple of logical axis names (see models/module.py);
this module maps them to ``PartitionSpec``s for a concrete mesh. Rules are a
plain dict so per-arch hillclimbing can override them (EXPERIMENTS.md §Perf).

Divisibility guard: a mesh axis is only assigned when it evenly divides the
dimension — otherwise the dim falls back to replication. This is what makes
one rule table serve all 10 archs (9-head GQA, 73448-vocab, batch=1
long-context cells, ...) without per-arch special cases.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# Default logical rules for the production (data, model) / (pod, data, model)
# meshes. FSDP over 'data' (params gathered per-layer under scan), TP over
# 'model', EP over 'model' for experts.
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "embed": ("data",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "lora": (None,),
}


def _mesh_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def spec_for(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
             mesh: Mesh, rules: Optional[Dict] = None) -> P:
    rules = rules or DEFAULT_RULES
    used = set()
    out = []
    for dim, name in zip(shape, axes):
        assigned = None
        if name is not None:
            for mesh_axis in rules.get(name, (None,)):
                if mesh_axis is None or mesh_axis in used:
                    continue
                if mesh_axis not in mesh.axis_names:
                    continue
                if dim % _mesh_size(mesh, mesh_axis) == 0:
                    assigned = mesh_axis
                    used.add(mesh_axis)
                    break
        out.append(assigned)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_shardings(axes_tree, shape_tree, mesh: Mesh, rules=None):
    """Pytree of NamedShardings matching the params tree."""
    def one(axes, shp):
        return NamedSharding(mesh, spec_for(tuple(axes), shp.shape, mesh,
                                            rules))
    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(isinstance(e, (str, type(None))) for e in x))


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that carry the batch dim (pure DP across pods)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_sharding(mesh: Mesh, ndim: int, batch_size: int,
                   seq_axis: Optional[str] = None, seq_len: int = 0) -> NamedSharding:
    """Batch sharded over the data axes (divisibility-guarded); optional
    sequence sharding (SP) on dim 1."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    first = dp if batch_size % dp_size == 0 else None
    rest = [None] * (ndim - 1)
    if seq_axis and ndim > 1 and seq_len % _mesh_size(mesh, seq_axis) == 0:
        rest[0] = seq_axis
    return NamedSharding(mesh, P(first, *rest))


def cache_sharding(mesh: Mesh, shape, batch_size: int) -> NamedSharding:
    """KV caches: batch over data axes, seq (dim 1) over 'model'.

    Falls back per-dim when sizes don't divide (e.g. batch=1 long-context:
    everything hangs off the seq dim instead)."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    spec = [None] * len(shape)
    if len(shape) >= 2:
        if batch_size % dp_size == 0:
            spec[0] = dp
            if shape[1] % _mesh_size(mesh, "model") == 0:
                spec[1] = "model"
        else:
            # batch too small: shard seq over both axes if possible
            if shape[1] % (dp_size * _mesh_size(mesh, "model")) == 0:
                spec[1] = tuple(dp) + ("model",)
            elif shape[1] % _mesh_size(mesh, "model") == 0:
                spec[1] = "model"
    return NamedSharding(mesh, P(*spec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
