"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis.

Provided as a composable module (tested on a multi-device host mesh). The
production 40-cell dry-run uses DP/FSDP/TP/EP meshes per the assignment —
on TPU ICI those dominate PP (MaxText practice); PP becomes relevant on
DCN-linked superpods, where this schedule applies across the `pipe` axis.

Implementation: ``shard_map`` over the pipe axis; each stage holds its own
layer stack; microbatches stream through with ``ppermute`` handoffs. The
schedule is the standard GPipe fill-drain: ``n_micro + n_stages - 1`` ticks.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_forward(mesh: Mesh, stage_fn, n_stages: int, n_micro: int):
    """Build a pipelined forward: x (n_micro, mb, ...) -> (n_micro, mb, ...).

    ``stage_fn(stage_params, x)`` applies one stage. ``stage_params`` must
    have a leading axis of size n_stages (one slice per stage).
    """

    def pipelined(stage_params, x):
        def per_stage(params_local, x_local):
            # params_local: this stage's params (leading axis 1); x_local:
            # microbatches on stage 0, zeros elsewhere.
            params_local = jax.tree.map(lambda a: a[0], params_local)
            stage_id = lax.axis_index("pipe")
            n_ticks = n_micro + n_stages - 1
            mb_shape = x_local.shape[1:]

            def tick(carry, t):
                buf, outputs = carry
                # stage 0 injects microbatch t (if in range)
                inject = jnp.where(t < n_micro, 1, 0)
                mb_in = lax.dynamic_index_in_dim(
                    x_local, jnp.minimum(t, n_micro - 1), 0, keepdims=False)
                cur = jnp.where((stage_id == 0) & (inject == 1), mb_in, buf)
                # run the stage
                y = stage_fn(params_local, cur)
                # last stage records its output at slot t - (n_stages - 1)
                slot = t - (n_stages - 1)
                write = (stage_id == n_stages - 1) & (slot >= 0)
                outputs = lax.cond(
                    write,
                    lambda o: lax.dynamic_update_index_in_dim(
                        o, y, jnp.maximum(slot, 0), 0),
                    lambda o: o, outputs)
                # hand off to the next stage
                nxt = lax.ppermute(
                    y, "pipe",
                    [(i, (i + 1) % n_stages) for i in range(n_stages)])
                return (nxt, outputs), None

            buf0 = jnp.zeros(mb_shape, x_local.dtype)
            outs0 = jnp.zeros((n_micro,) + mb_shape, x_local.dtype)
            (_, outputs), _ = lax.scan(
                tick, (buf0, outs0), jnp.arange(n_micro + n_stages - 1))
            # only the last stage holds real outputs; psum broadcasts them
            # (all other stages contribute zeros)
            return lax.psum(outputs, "pipe")

        return shard_map(
            per_stage, mesh=mesh,
            in_specs=(P("pipe"), P()),       # params split by stage; x replicated
            out_specs=P(),                    # outputs replicated (from last stage)
            check_rep=False,
        )(stage_params, x)

    return pipelined
