"""Size-bucketed request batching — padding + stacking (DESIGN.md §9).

Serving traffic arrives with arbitrary geometry sizes; compiling one XLA
executable per (m, n) pair is the naive-serving failure mode (every new
shape pays ~seconds of compile). The batching layer rounds every request
up to a small set of **size buckets** and executes each bucket as one
vmapped stack under one jit, so steady-state traffic touches a handful of
executables no matter how diverse the request shapes are.

Padding discipline (the PR-3 lesson, DESIGN.md §6): padded slots get
weight ``PAD_WEIGHT = 1e-30`` — a *normal* float32, because XLA CPU
flushes subnormals and a flushed-to-zero weight re-enters kernels through
``log``/clamp paths as full-mass garbage. Padded cost/point/feature slots
are zero. A padded slot then carries ~1e-30 of coupling mass: its
contribution to the objective and to the real slots' Sinkhorn updates
sits ~30 decades below the live entries, under float32 resolution — the
real block of a padded solve matches the unpadded solve to rtol ≲ 1e-5
(regression-tested at the serving boundary).

Batch-lane padding is a separate axis: a flush with fewer requests than
the lane count is topped up with **filler lanes** replicating lane 0
(fault hooks disarmed). vmap lanes are mathematically independent, and
the while-loop driver's lane-freeze semantics (DESIGN.md §8) make them
bitwise independent in practice — a request solved next to fillers or
poisoned lane-mates returns exactly its solo result.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.api.geometry import Geometry
from repro.api.problem import QuadraticProblem

# pad weight: the smallest *normal* float32 scale that survives XLA CPU's
# subnormal flush (same constant as multiscale's _PAD_WEIGHT / lowrank's
# _TINY — the PR-3 defect class)
PAD_WEIGHT = 1e-30

# default geometry-size buckets: dense-ish coverage where small-problem
# traffic lives, power-of-two spacing above
DEFAULT_BUCKETS = (16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512)


def bucket_for(n: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    """Smallest configured bucket ≥ n; beyond the largest, the next power
    of two (shape diversity is already negligible up there)."""
    if n <= 0:
        raise ValueError(f"geometry size must be positive, got {n}")
    for b in sorted(buckets):
        if n <= b:
            return b
    b = 1
    while b < n:
        b <<= 1
    return b


# Never dispatch a width-1 stack: XLA collapses a degenerate batch-1
# dot_general into a different gemm lowering than both the eager solve and
# every width ≥ 2 stack, so width-1 is the one batch shape whose per-lane
# bits differ from all others (measured on CPU). With a floor of 2, a
# request's bits are invariant to batch width AND equal to its eager
# ``repro.solve`` bits — the property the serving-boundary inertness
# tests pin down.
MIN_LANES = 2


def next_pow2(n: int) -> int:
    """Lane-count rounding: batch widths are powers of two (with a floor
    of :data:`MIN_LANES`) so partially filled flushes reuse the same
    executables as full ones — and per-lane bits stay width-invariant."""
    b = MIN_LANES
    while b < max(1, n):
        b <<= 1
    return b


def _pad_matrix(x, rows: int, cols: int):
    return jnp.pad(jnp.asarray(x),
                   ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


def pad_geometry(geom: Geometry, nb: int) -> Geometry:
    """Pad one geometry to bucket size ``nb`` (weights at PAD_WEIGHT,
    cost/points/features zero-padded). No-op when already at size."""
    n = geom.n
    if n > nb:
        raise ValueError(f"geometry of size {n} does not fit bucket {nb}")
    if n == nb:
        return geom
    pad = nb - n
    weights = jnp.pad(jnp.asarray(geom.weights), (0, pad),
                      constant_values=PAD_WEIGHT)
    cost = None if geom.cost is None else _pad_matrix(geom.cost, nb, nb)
    points = (None if geom.points is None
              else _pad_matrix(geom.points, nb, geom.points.shape[1]))
    features = (None if geom.features is None
                else _pad_matrix(geom.features, nb, geom.features.shape[1]))
    return Geometry(cost, weights, features=features, points=points,
                    validate=False)


def pad_problem(problem: QuadraticProblem, mb: int, nb: int,
                geom_x=None, geom_y=None) -> QuadraticProblem:
    """Pad a problem to bucket shape (mb, nb). Callers holding cached
    padded geometries pass them via ``geom_x``/``geom_y`` (the serving
    hot path); otherwise both sides are padded here."""
    gx = pad_geometry(problem.geom_x, mb) if geom_x is None else geom_x
    gy = pad_geometry(problem.geom_y, nb) if geom_y is None else geom_y
    M = None if problem.M is None else _pad_matrix(problem.M, mb, nb)
    return QuadraticProblem(gx, gy, loss=problem.loss,
                            fused_penalty=problem.fused_penalty, M=M,
                            lam=problem.lam, validate=False)


def batch_signature(item) -> Any:
    """Hashable executable identity of one padded (problem, solver, key)
    tuple: the pytree structure (which carries every static knob — loss,
    solver meta fields, None-presence) plus the shape/dtype of every
    leaf. Two requests share a bucket iff their signatures match — then
    stacking is well-defined and the vmapped executable is shared."""
    leaves, treedef = jax.tree.flatten(item)
    avals = tuple((jnp.shape(leaf), jnp.result_type(leaf))
                  for leaf in leaves)
    return (treedef, avals)


def stack_items(items: Sequence[Any]):
    """Stack same-signature (problem, solver, key) tuples into one
    batched pytree (leading axis = lane)."""
    return jax.tree.map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                        *items)


def disarm_fault(solver):
    """A copy of ``solver`` with any fault hook disarmed (at_iter=-1) —
    filler lanes replicate a real lane's config but must never fire its
    chaos hook."""
    fault = getattr(solver, "fault", None)
    if fault is None:
        return solver
    return dataclasses.replace(
        solver, fault=dataclasses.replace(fault, at_iter=jnp.int32(-1)))
