"""``GWServer`` — the batched, cached, observable solve front door.

Request lifecycle (DESIGN.md §9):

    server = GWServer()
    rid = server.submit(problem, solver="dense_gw", key=key)   # enqueue
    server.poll(rid)        # "queued" | "running" | "done"
    res = server.result(rid)            # blocks; RequestResult

``submit`` resolves the solver (same rules as ``repro.solve``), pads both
geometries to size buckets through the :class:`GeometryCache`, and
enqueues the request under its **batch signature** (padded pytree
structure + leaf avals). A bucket flushes when it reaches
``max_batch`` requests or its oldest request is older than
``max_wait_s`` — enforced by a background flusher thread (daemon, ticks
at ``max_wait_s / 4``; disable with ``ServeConfig(flush_thread=False)``
to fall back to the PR-7 cooperative mode where the deadline is only
checked on submit/poll/result/flush calls). Server state is guarded by
one re-entrant lock, so submits and timer flushes interleave safely.

A flush stacks the bucket into one vmapped jit call — filler lanes
(replicas of lane 0 with fault hooks disarmed) round the lane count up to
a power of two so partial flushes reuse full-batch executables. Dispatch
is **asynchronous**: the jitted call returns device futures immediately
(input stack buffers are donated), so the next bucket accumulates while
XLA computes; ``result`` blocks on the batch and slices out one lane.

Failure semantics are **per request**: each lane carries its own
:class:`~repro.health.status.SolveStatus` (the health layer's vmap
lane-isolation guarantee — one poisoned request cannot touch its
bucket-mates' bits), and a lane that comes back DIVERGED/STALLED is —
under ``on_failure="fallback"`` — re-solved solo through
``repro.solve(..., on_failure="fallback")``, walking the PR-6 solver
ladder for that request only.
"""
from __future__ import annotations

import threading
import time
import warnings
import weakref
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.api.problem import QuadraticProblem
from repro.api.solve import select_solver
from repro.api.solvers import get_solver
from repro.health.status import STALLED, STATUS_NAMES
from repro.serve.batching import (
    DEFAULT_BUCKETS,
    batch_signature,
    bucket_for,
    disarm_fault,
    next_pow2,
    pad_problem,
    stack_items,
)
from repro.obs.registry import registry
from repro.obs.span import span
from repro.serve.cache import GeometryCache
from repro.serve.metrics import ServeMetrics


@dataclass(frozen=True)
class ServeConfig:
    """Server policy knobs.

    buckets       — geometry-size buckets requests are padded up to
    max_batch     — flush a bucket once it holds this many requests
    max_wait_s    — flush a non-empty bucket once its oldest request has
                    waited this long (enforced by the flusher thread;
                    with ``flush_thread=False``, checked cooperatively on
                    every server call)
    flush_thread  — run a background daemon thread that ticks every
                    ``max_wait_s / 4`` and flushes overdue buckets, so
                    ``max_wait_s`` is honored in wall-clock time even
                    when no server call arrives
    cache_entries — GeometryCache capacity (artifacts, LRU)
    on_failure    — per-request policy for unhealthy lanes: "none"
                    returns the DIVERGED/STALLED output as-is (inspect
                    ``RequestResult.status``); "fallback" re-solves the
                    request solo via ``repro.solve(on_failure=
                    "fallback")`` (the PR-6 solver ladder)
    donate        — donate the stacked problem buffers to the executor
                    (they are per-flush temporaries; donation lets XLA
                    reuse them for outputs)
    compilation_cache_dir — when set, enable JAX's *persistent*
                    compilation cache at this path before the first
                    dispatch: a fresh process serving the same bucket
                    shapes deserializes yesterday's executables instead
                    of recompiling them (the dominant cold-start cost).
                    The knob is process-global (it flips ``jax.config``
                    for every jit in the process, not just the server's)
                    and sticky — enabling is one-way for the process
                    lifetime, later servers may point elsewhere only
                    with a fresh process.
    """
    buckets: Tuple[int, ...] = DEFAULT_BUCKETS
    max_batch: int = 8
    max_wait_s: float = 0.02
    flush_thread: bool = True
    cache_entries: int = 128
    on_failure: str = "fallback"
    donate: bool = True
    compilation_cache_dir: Optional[str] = None

    def __post_init__(self):
        if self.on_failure not in ("none", "fallback"):
            raise ValueError(
                f"on_failure must be 'none' or 'fallback', got "
                f"{self.on_failure!r}")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")


def enable_compilation_cache(cache_dir: str) -> None:
    """Point JAX's persistent compilation cache at ``cache_dir``.

    Caches every XLA executable compiled from now on (and reloads on
    cache hits in future processes). The thresholds are zeroed so even
    sub-second solver compiles are persisted — a GW serving process
    compiles a handful of large executables, not thousands of tiny
    ones, so write amplification is a non-issue.
    """
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)


@dataclass
class RequestResult:
    """One request's outcome.

    output is the per-lane ``GWOutput`` at the *padded* bucket shape
    (``padded_shape``) — or, when ``fell_back``, the fallback solve's
    output at the original shape. ``coupling_dense()`` always returns the
    original-shape coupling.
    """
    rid: int
    value: float
    output: Any
    status: Any                       # per-request SolveStatus
    status_name: str
    failed: bool                      # unhealthy after the batched attempt
    fell_back: bool                   # recovered via the solver ladder
    shape: Tuple[int, int]            # original (m, n)
    padded_shape: Tuple[int, int]
    latency_s: float

    def coupling_dense(self):
        m, n = self.shape
        dense = self.output.coupling_dense(*(
            self.shape if self.fell_back else self.padded_shape))
        return dense[:m, :n]


@dataclass
class _Request:
    rid: int
    problem: QuadraticProblem         # original, unpadded
    solver: Any
    key: Any
    item: Any                         # (padded problem, solver, key)
    sig: Any
    shape: Tuple[int, int]
    padded_shape: Tuple[int, int]
    submitted_at: float
    state: str = "queued"             # queued -> running -> done
    batch: Any = None
    lane: int = -1
    result: Optional[RequestResult] = None


@dataclass
class _Batch:
    out: Any                          # stacked GWOutput (device futures)
    rids: List[int]                   # real lanes, in lane order
    n_lanes: int
    dispatched_at: float = field(default_factory=time.perf_counter)


def _run_lane(problem, solver, key):
    return solver.run(problem, key)


def _flusher_main(server_ref, interval_s: float,
                  stop: threading.Event) -> None:
    """Wall-clock flusher loop: pump overdue buckets every ``interval_s``.

    Holds only a weakref to the server so an abandoned (un-``close``d)
    server can still be garbage collected; the loop exits when the
    server dies or ``stop`` is set.
    """
    while not stop.wait(interval_s):
        server = server_ref()
        if server is None:
            return
        try:
            server._pump(source="timer")
        except Exception:  # noqa: BLE001 — the flusher must outlive hiccups
            pass
        del server


class GWServer:
    """Batched, cached, observable front door over the solver registry."""

    def __init__(self, config: Optional[ServeConfig] = None):
        self.config = config or ServeConfig()
        if self.config.compilation_cache_dir:
            enable_compilation_cache(self.config.compilation_cache_dir)
        self.cache = GeometryCache(self.config.cache_entries)
        self.metrics = ServeMetrics()
        self._requests: Dict[int, _Request] = {}
        self._queues: Dict[Any, List[int]] = {}
        self._next_rid = 0
        self._lock = threading.RLock()
        donate = (0,) if self.config.donate else ()
        self._exec = jax.jit(jax.vmap(_run_lane), donate_argnums=donate)
        self._flusher_stop = threading.Event()
        self._flusher: Optional[threading.Thread] = None
        if self.config.flush_thread and self.config.max_wait_s > 0:
            self._flusher = threading.Thread(
                target=_flusher_main,
                args=(weakref.ref(self), self.config.max_wait_s / 4,
                      self._flusher_stop),
                name="gwserver-flusher", daemon=True)
            self._flusher.start()

    def close(self) -> None:
        """Stop the background flusher thread (idempotent). Queued
        requests stay retrievable via ``result``/``results``."""
        self._flusher_stop.set()
        if self._flusher is not None:
            self._flusher.join(timeout=1.0)
            self._flusher = None

    def __del__(self):
        try:
            self._flusher_stop.set()
        except Exception:  # noqa: BLE001 — interpreter teardown
            pass

    # -- submit -------------------------------------------------------------

    def submit(self, problem: QuadraticProblem,
               solver: Union[str, Any, None] = None,
               key: Optional[jax.Array] = None,
               validate: bool = True) -> int:
        """Enqueue one solve request; returns its request id."""
        with span("serve.submit"):
            if solver is None:
                solver = select_solver(problem)
            elif isinstance(solver, str):
                solver = get_solver(solver).default_config(
                    max(problem.shape))
            if key is None and getattr(type(solver), "requires_key", False):
                raise ValueError(
                    f"{type(solver).__name__} needs a PRNG key: "
                    f"submit(problem, solver, key=jax.random.PRNGKey(seed))")
            if validate and not getattr(problem, "_validated", False):
                problem.check()
            m, n = problem.shape
            mb = bucket_for(m, self.config.buckets)
            nb = bucket_for(n, self.config.buckets)
            with span("serve.pad"):
                padded = pad_problem(
                    problem, mb, nb,
                    geom_x=self.cache.padded(problem.geom_x, mb),
                    geom_y=self.cache.padded(problem.geom_y, nb))
            item = (padded, solver, key)
            sig = batch_signature(item)
            with self._lock:
                rid = self._next_rid
                self._next_rid += 1
                req = _Request(rid=rid, problem=problem, solver=solver,
                               key=key, item=item, sig=sig, shape=(m, n),
                               padded_shape=(mb, nb),
                               submitted_at=self.metrics.record_submit())
                self._requests[rid] = req
                self._queues.setdefault(sig, []).append(rid)
                if len(self._queues[sig]) >= self.config.max_batch:
                    self._flush_bucket(sig, source="full")
                else:
                    self._pump()
            return rid

    # -- flushing -----------------------------------------------------------

    def _pump(self, source: str = "call") -> None:
        """Flush every bucket whose oldest request exceeded max_wait_s.
        ``source`` tags the dispatch span: "call" for cooperative checks
        on server calls, "timer" for the background flusher thread."""
        with self._lock:
            now = time.perf_counter()
            for sig in list(self._queues):
                rids = self._queues[sig]
                if rids and (now - self._requests[rids[0]].submitted_at
                             >= self.config.max_wait_s):
                    self._flush_bucket(sig, source=source)

    def flush(self) -> None:
        """Dispatch every non-empty bucket immediately."""
        with self._lock:
            for sig in list(self._queues):
                if self._queues[sig]:
                    self._flush_bucket(sig, source="flush")

    def _flush_bucket(self, sig, source: str = "call") -> None:
        with self._lock:
            rids = self._queues.pop(sig, [])
            if not rids:
                return
            items = [self._requests[rid].item for rid in rids]
            n_lanes = next_pow2(len(items))
            if len(items) < n_lanes:
                p0, s0, k0 = items[0]
                items.extend([(p0, disarm_fault(s0), k0)]
                             * (n_lanes - len(items)))
            with span("serve.batch", lanes=n_lanes, real=len(rids)):
                stacked_p, stacked_s, stacked_k = stack_items(items)
            with span("serve.dispatch", lanes=n_lanes,
                      source=source) as sp:
                before = self._exec_cache_size()
                with warnings.catch_warnings():
                    # CPU backends can't alias every donated buffer —
                    # harmless
                    warnings.filterwarnings(
                        "ignore",
                        message="Some donated buffers were not usable")
                    out = self._exec(stacked_p, stacked_s, stacked_k)
                sp["compiled"] = bool(before >= 0
                                      and self._exec_cache_size() > before)
            batch = _Batch(out=out, rids=rids, n_lanes=n_lanes)
            self.metrics.record_batch(len(rids), n_lanes)
            for lane, rid in enumerate(rids):
                req = self._requests[rid]
                req.state = "running"
                req.batch = batch
                req.lane = lane

    def _exec_cache_size(self) -> int:
        try:
            return self._exec._cache_size()
        except Exception:  # noqa: BLE001 — observability only
            return -1

    # -- poll / result ------------------------------------------------------

    def poll(self, rid: int) -> str:
        """Non-blocking state of a request: queued / running / done.
        Also advances time-based flushes (cooperative scheduling)."""
        with self._lock:
            req = self._req(rid)
        self._pump()
        if req.state == "running":
            value = req.batch.out.value
            if getattr(value, "is_ready", lambda: True)():
                return "done"
        return "done" if req.state == "done" else req.state

    def result(self, rid: int) -> RequestResult:
        """Block until the request's batch completes; per-request outcome."""
        with self._lock:
            req = self._req(rid)
            if req.result is not None:
                return req.result
            if req.state == "queued":
                self._flush_bucket(req.sig)
            batch = req.batch
        # block outside the lock: the flusher and other submitters keep
        # running while XLA computes
        with span("serve.block"):
            jax.block_until_ready(batch.out.value)
        with self._lock:
            if req.result is not None:     # lost a race to another thread
                return req.result
            lane = req.lane
            out = jax.tree.map(lambda x: x[lane], batch.out)
            failed = bool(np.asarray(out.status.code) >= STALLED) or not \
                bool(np.all(np.isfinite(np.asarray(out.value))))
            fell_back = False
            if failed and self.config.on_failure == "fallback":
                with span("serve.fallback", rid=rid):
                    out, fell_back = self._fallback(req)
            status_name = (STATUS_NAMES[int(np.asarray(out.status.code))]
                           if out.status is not None else "UNKNOWN")
            latency = self.metrics.record_result(
                req.submitted_at, batch.dispatched_at, failed, fell_back)
            req.state = "done"
            req.result = RequestResult(
                rid=rid, value=float(np.asarray(out.value)), output=out,
                status=out.status, status_name=status_name, failed=failed,
                fell_back=fell_back, shape=req.shape,
                padded_shape=req.padded_shape, latency_s=latency)
            req.batch = None          # release the stacked batch for GC
            req.item = None
            return req.result

    def results(self, rids: Sequence[int]) -> List[RequestResult]:
        """Drain a set of requests (flushes any still queued)."""
        self.flush()
        return [self.result(rid) for rid in rids]

    def _fallback(self, req: _Request):
        """Re-solve one failed request solo through the PR-6 ladder. The
        original (unpadded) problem is used — the fallback path owes the
        caller a healthy answer, not a bucket-shaped one."""
        import repro
        try:
            out = repro.solve(req.problem, req.solver, key=req.key,
                              on_failure="fallback")
        except Exception:  # noqa: BLE001 — fallback is best-effort
            return jax.tree.map(lambda x: x[req.lane], req.batch.out), False
        recovered = bool(np.asarray(out.status.code) < STALLED) and bool(
            np.all(np.isfinite(np.asarray(out.value))))
        if not recovered:
            return jax.tree.map(lambda x: x[req.lane], req.batch.out), False
        return out, True

    # -- observability ------------------------------------------------------

    def stats(self) -> dict:
        """One flat dict: request/batch/latency metrics + cache counters."""
        return self.metrics.summary(self.cache.stats())

    def metrics_text(self) -> str:
        """The process-wide metrics registry (including this server's
        ``repro_serve_*`` series) in Prometheus text exposition format —
        the payload ``launch/serve.py --metrics-port`` serves."""
        return registry().prometheus_text()

    def reset_stats(self) -> None:
        """Zero metrics and cache counters, keeping compiled executables
        and cached artifacts warm — the steady-state measurement hook."""
        self.metrics = ServeMetrics()
        self.cache.reset_counters()

    def _req(self, rid: int) -> _Request:
        try:
            return self._requests[rid]
        except KeyError:
            raise KeyError(f"unknown request id {rid}") from None
