"""Server observability — request/batch counters + latency percentiles.

One ``ServeMetrics`` instance rides on each :class:`~repro.serve.server
.GWServer`; every counter is cheap host-side bookkeeping (no device
syncs), and :meth:`summary` flattens everything — including the geometry
cache's hit/miss/eviction stats — into one JSON-ready dict, which is what
``benchmarks/bench_serve.py`` records into ``BENCH_PR7.json`` and the
serve-smoke CI job asserts on.

``percentiles`` is the shared p50/p95/p99 helper: ``benchmarks/common.py``
re-exports it so every BENCH_*.json writer reports the same tail
statistics (satellite of PR 7 — means hide exactly the tail a serving
layer exists to control).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import numpy as np

DEFAULT_QS = (50, 95, 99)


def percentiles(samples: Sequence[float],
                qs: Sequence[int] = DEFAULT_QS) -> Dict[str, float]:
    """``{"p50": ..., "p95": ..., "p99": ...}`` of ``samples`` (linear
    interpolation; empty input yields NaNs so callers can't mistake "no
    data" for "zero latency")."""
    if len(samples) == 0:
        return {f"p{q}": float("nan") for q in qs}
    arr = np.asarray(list(samples), dtype=np.float64)
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}


class ServeMetrics:
    """Counters + latency recorder for one server instance."""

    def __init__(self):
        self.n_submitted = 0
        self.n_completed = 0
        self.n_failed = 0        # unhealthy after the batched attempt
        self.n_fallbacks = 0     # per-request fallback re-solves taken
        self.n_batches = 0
        self.n_lanes = 0         # total dispatched lanes incl. filler
        self.n_filler_lanes = 0
        self.latencies_s: List[float] = []
        self.queue_waits_s: List[float] = []
        self._t0 = time.perf_counter()

    # -- recording ----------------------------------------------------------

    def record_submit(self) -> float:
        self.n_submitted += 1
        return time.perf_counter()

    def record_batch(self, n_real: int, n_lanes: int) -> None:
        self.n_batches += 1
        self.n_lanes += n_lanes
        self.n_filler_lanes += n_lanes - n_real

    def record_result(self, submitted_at: float, dispatched_at: float,
                      failed: bool, fell_back: bool) -> float:
        now = time.perf_counter()
        latency = now - submitted_at
        self.n_completed += 1
        self.latencies_s.append(latency)
        self.queue_waits_s.append(dispatched_at - submitted_at)
        if failed:
            self.n_failed += 1
        if fell_back:
            self.n_fallbacks += 1
        return latency

    # -- reporting ----------------------------------------------------------

    def summary(self, cache_stats: Optional[dict] = None) -> dict:
        elapsed = time.perf_counter() - self._t0
        lat = percentiles(self.latencies_s)
        out = {
            "n_submitted": self.n_submitted,
            "n_completed": self.n_completed,
            "n_failed": self.n_failed,
            "n_fallbacks": self.n_fallbacks,
            "n_batches": self.n_batches,
            "mean_batch_lanes": (self.n_lanes / self.n_batches
                                 if self.n_batches else 0.0),
            "filler_lane_frac": (self.n_filler_lanes / self.n_lanes
                                 if self.n_lanes else 0.0),
            "throughput_rps": (self.n_completed / elapsed
                               if elapsed > 0 else 0.0),
            "latency_p50_ms": lat["p50"] * 1e3,
            "latency_p95_ms": lat["p95"] * 1e3,
            "latency_p99_ms": lat["p99"] * 1e3,
            "queue_wait_p50_ms": percentiles(
                self.queue_waits_s, (50,))["p50"] * 1e3,
        }
        if cache_stats is not None:
            out.update({f"cache_{k}": v for k, v in cache_stats.items()})
        return out
