"""Server observability — request/batch counters + latency percentiles.

One ``ServeMetrics`` instance rides on each :class:`~repro.serve.server
.GWServer`; every counter is cheap host-side bookkeeping (no device
syncs), and :meth:`summary` flattens everything — including the geometry
cache's hit/miss/eviction stats — into one JSON-ready dict, which is what
``benchmarks/bench_serve.py`` records into ``BENCH_PR7.json`` and the
serve-smoke CI job asserts on.

Latency samples live in bounded :class:`~repro.obs.registry.Reservoir`
stores (exact percentiles up to ``sample_cap`` = 8192 samples, unbiased
uniform reservoir sampling beyond — the PR-7 append-only lists grew
without bound on long-lived servers). Every counter and latency is also
mirrored into the process-wide obs registry under ``repro_serve_*`` /
``repro_cache_*`` names, so the Prometheus exporter
(``GWServer.metrics_text()`` / ``launch/serve.py --metrics-port``) sees
server traffic without a second bookkeeping path.

``percentiles`` moved to ``repro.obs.registry`` with the unified
telemetry layer; it is re-exported here (same name, same behavior) for
the PR-7 callers.
"""
from __future__ import annotations

import time
from typing import Optional

from repro.obs.registry import (  # noqa: F401 — re-exported shims
    DEFAULT_QS,
    DEFAULT_RESERVOIR_CAP,
    Reservoir,
    percentiles,
    registry,
)


class ServeMetrics:
    """Counters + bounded latency recorder for one server instance.

    sample_cap — reservoir size for latency/queue-wait samples: exact
    percentiles up to this many completed requests, a uniform sample of
    the full history beyond (default 8192; memory stays O(cap) forever).
    """

    def __init__(self, sample_cap: int = DEFAULT_RESERVOIR_CAP):
        self.n_submitted = 0
        self.n_completed = 0
        self.n_failed = 0        # unhealthy after the batched attempt
        self.n_fallbacks = 0     # per-request fallback re-solves taken
        self.n_batches = 0
        self.n_lanes = 0         # total dispatched lanes incl. filler
        self.n_filler_lanes = 0
        self.sample_cap = sample_cap
        self.latencies_s = Reservoir(sample_cap)
        self.queue_waits_s = Reservoir(sample_cap)
        self._t0 = time.perf_counter()

    # -- recording ----------------------------------------------------------

    def record_submit(self) -> float:
        self.n_submitted += 1
        registry().counter("repro_serve_requests_total",
                           "requests submitted to GWServer").inc()
        return time.perf_counter()

    def record_batch(self, n_real: int, n_lanes: int) -> None:
        self.n_batches += 1
        self.n_lanes += n_lanes
        self.n_filler_lanes += n_lanes - n_real
        reg = registry()
        reg.counter("repro_serve_batches_total",
                    "vmapped batches dispatched").inc()
        reg.counter("repro_serve_lanes_total",
                    "dispatched lanes incl. filler").inc(n_lanes)
        reg.counter("repro_serve_filler_lanes_total",
                    "pow2-padding filler lanes dispatched").inc(
                        n_lanes - n_real)

    def record_result(self, submitted_at: float, dispatched_at: float,
                      failed: bool, fell_back: bool) -> float:
        now = time.perf_counter()
        latency = now - submitted_at
        queue_wait = dispatched_at - submitted_at
        self.n_completed += 1
        self.latencies_s.add(latency)
        self.queue_waits_s.add(queue_wait)
        if failed:
            self.n_failed += 1
        if fell_back:
            self.n_fallbacks += 1
        reg = registry()
        reg.histogram("repro_serve_latency_seconds",
                      "submit-to-result request latency").observe(latency)
        reg.histogram("repro_serve_queue_wait_seconds",
                      "submit-to-dispatch queue wait").observe(queue_wait)
        if failed:
            reg.counter("repro_serve_failed_total",
                        "requests unhealthy after the batched attempt").inc()
        if fell_back:
            reg.counter("repro_serve_fallbacks_total",
                        "per-request solo fallback re-solves").inc()
        return latency

    # -- reporting ----------------------------------------------------------

    def summary(self, cache_stats: Optional[dict] = None) -> dict:
        elapsed = time.perf_counter() - self._t0
        lat = percentiles(self.latencies_s)
        out = {
            "n_submitted": self.n_submitted,
            "n_completed": self.n_completed,
            "n_failed": self.n_failed,
            "n_fallbacks": self.n_fallbacks,
            "n_batches": self.n_batches,
            "mean_batch_lanes": (self.n_lanes / self.n_batches
                                 if self.n_batches else 0.0),
            "filler_lane_frac": (self.n_filler_lanes / self.n_lanes
                                 if self.n_lanes else 0.0),
            "throughput_rps": (self.n_completed / elapsed
                               if elapsed > 0 else 0.0),
            "latency_p50_ms": lat["p50"] * 1e3,
            "latency_p95_ms": lat["p95"] * 1e3,
            "latency_p99_ms": lat["p99"] * 1e3,
            "queue_wait_p50_ms": percentiles(
                self.queue_waits_s, (50,))["p50"] * 1e3,
        }
        if cache_stats is not None:
            out.update({f"cache_{k}": v for k, v in cache_stats.items()})
        return out
