"""GW-as-a-service: batched, cached, observable solving (DESIGN.md §9).

The production front door over ``repro.solve``: size-bucketed request
batching (one vmapped jit per bucket signature), a content-hash-keyed
geometry artifact cache, asynchronous dispatch with donated buffers, and
per-request health/fallback semantics.

    from repro.serve import GWServer, ServeConfig

    server = GWServer(ServeConfig(max_batch=8))
    rids = [server.submit(p, solver="dense_gw") for p in problems]
    for res in server.results(rids):
        print(res.rid, res.value, res.status_name, res.latency_s)
    print(server.stats())
"""
from repro.serve.batching import (
    DEFAULT_BUCKETS,
    PAD_WEIGHT,
    batch_signature,
    bucket_for,
    next_pow2,
    pad_geometry,
    pad_problem,
)
from repro.serve.cache import GeometryCache
from repro.serve.metrics import ServeMetrics, percentiles
from repro.serve.server import (
    GWServer,
    RequestResult,
    ServeConfig,
    enable_compilation_cache,
)

__all__ = [
    "GWServer",
    "ServeConfig",
    "RequestResult",
    "enable_compilation_cache",
    "GeometryCache",
    "ServeMetrics",
    "percentiles",
    "bucket_for",
    "next_pow2",
    "pad_geometry",
    "pad_problem",
    "batch_signature",
    "DEFAULT_BUCKETS",
    "PAD_WEIGHT",
]
