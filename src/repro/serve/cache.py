"""Geometry artifact cache — compute per-geometry work once (DESIGN.md §9).

The serving-economics observation (Scetbon et al., arXiv 2106.01128, via
PAPERS.md): most per-solve setup work depends on **one geometry only** —
padding + device placement of the cost/points/weights, the exact
rank-(d+2) point-cloud cost factors ``U Vᵀ`` the low-rank family
consumes, and the multiscale anchor selection. In a catalog-matching
workload ("match every request against a reference shape") the reference
side recurs across requests, so these artifacts amortize to ~zero.

``GeometryCache`` is a size-bounded LRU keyed on
``(Geometry.content_hash(), artifact tag)`` with hit/miss/eviction
counters. The server's batched hot path consumes the ``padded/<n>``
artifact on every submit; ``lowrank_factors`` and ``anchors`` are built
by :meth:`warm` for catalog references — they are host-side inputs for
artifact-aware pipelines (threading them *into* the jitted solve as
pytree inputs is the planned follow-up; solvers currently rebuild them
in-trace, where XLA at least amortizes them per executable).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Tuple

import jax

from repro.api.geometry import Geometry
from repro.obs.registry import registry
from repro.serve.batching import pad_geometry


class GeometryCache:
    """LRU of per-geometry artifacts keyed on content hash + tag.

    max_entries — capacity in artifacts (not bytes); least recently used
                  artifacts are evicted first. Counters: ``hits`` /
                  ``misses`` / ``evictions``.
    """

    def __init__(self, max_entries: int = 128):
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = max_entries
        self._store: "OrderedDict[Tuple[str, Any], Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._store)

    def get_or_build(self, geom: Geometry, tag: Any,
                     build: Callable[[Geometry], Any]) -> Any:
        """The cached artifact ``tag`` of ``geom``, building (and
        inserting) it on miss."""
        key = (geom.content_hash(), tag)
        if key in self._store:
            self.hits += 1
            registry().counter("repro_cache_hits_total",
                               "GeometryCache artifact hits").inc()
            self._store.move_to_end(key)
            return self._store[key]
        self.misses += 1
        registry().counter("repro_cache_misses_total",
                           "GeometryCache artifact misses").inc()
        artifact = build(geom)
        self._store[key] = artifact
        while len(self._store) > self.max_entries:
            self._store.popitem(last=False)
            self.evictions += 1
            registry().counter("repro_cache_evictions_total",
                               "GeometryCache LRU evictions").inc()
        return artifact

    # -- built-in artifact kinds -------------------------------------------

    def padded(self, geom: Geometry, nb: int) -> Geometry:
        """``geom`` padded to bucket size ``nb`` — the batched hot path's
        per-request artifact (skips re-padding + re-hashing + host→device
        transfer for recurring geometries)."""
        return self.get_or_build(geom, ("padded", nb),
                                 lambda g: pad_geometry(g, nb))

    def lowrank_factors(self, geom: Geometry):
        """Exact rank-(d+2) squared-euclidean cost factors of a
        point-cloud geometry (lowrank/factorize.py)."""
        if not geom.is_point_cloud:
            raise ValueError(
                "lowrank_factors is a point-cloud artifact; this geometry "
                "only carries an explicit cost matrix")
        from repro.lowrank.factorize import sq_euclidean_factors
        return self.get_or_build(
            geom, ("lr_factors",),
            lambda g: jax.block_until_ready(sq_euclidean_factors(g.points)))

    def anchors(self, geom: Geometry, k: int, method: str = "fps"):
        """Multiscale anchor selection for ``geom`` (multiscale/anchors).
        Keyed per (k, method); the PRNG key is derived from the content
        hash, so the artifact is a pure function of the geometry."""
        from repro.multiscale.anchors import select_anchors
        seed = int(geom.content_hash()[:8], 16)

        def build(g):
            return jax.block_until_ready(select_anchors(
                jax.random.PRNGKey(seed), g.cost_matrix, g.weights, k,
                method=method))
        return self.get_or_build(geom, ("anchors", k, method), build)

    def warm(self, geom: Geometry, buckets=(), k: int = 0) -> None:
        """Precompute a catalog reference's artifacts: padded copies for
        each bucket in ``buckets``, low-rank factors when the geometry is
        a point cloud, anchors when ``k > 0``."""
        for nb in buckets:
            self.padded(geom, nb)
        if geom.is_point_cloud:
            self.lowrank_factors(geom)
        if k > 0:
            self.anchors(geom, k)

    def reset_counters(self) -> None:
        """Zero the hit/miss/eviction counters, keeping cached artifacts —
        lets benchmarks measure a steady-state pass on a warm cache."""
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "entries": len(self._store),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": self.hits / total if total else 0.0,
        }
