"""Unified telemetry layer (DESIGN.md §10).

Three instruments, one report:

* **in-jit convergence traces** — :class:`ConvergenceTrace` buffers the
  health loop fills per outer iteration (opt-in via ``solver.trace=True``;
  ``None``/zero-leaf and bitwise-identical outputs when off);
* **solve-lifecycle spans** — :func:`span`, host-side nestable timing
  scopes over ``solve()`` and ``GWServer`` stages;
* **process-wide metrics** — :func:`registry`, counters/gauges/histograms
  every subsystem registers into, exported as JSON (:meth:`MetricsRegistry.
  snapshot` / ``write_jsonl``) and Prometheus text
  (:meth:`MetricsRegistry.prometheus_text`, served by
  :func:`serve_metrics_http`).

:func:`report` assembles all three into one JSON document.
"""
from repro.obs.http import serve_metrics_http
from repro.obs.registry import (
    DEFAULT_QS,
    DEFAULT_RESERVOIR_CAP,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Reservoir,
    percentiles,
    registry,
    validate_exposition,
)
from repro.obs.report import note_solve, report
from repro.obs.span import (
    MAX_SPANS,
    clear_spans,
    configure,
    span,
    span_breakdown,
    spans,
)
from repro.obs.trace import (
    ConvergenceTrace,
    empty_trace,
    n_valid,
    trace_to_dict,
)

__all__ = [
    "ConvergenceTrace",
    "Counter",
    "DEFAULT_QS",
    "DEFAULT_RESERVOIR_CAP",
    "Gauge",
    "Histogram",
    "MAX_SPANS",
    "MetricsRegistry",
    "Reservoir",
    "clear_spans",
    "configure",
    "empty_trace",
    "n_valid",
    "note_solve",
    "percentiles",
    "registry",
    "report",
    "serve_metrics_http",
    "span",
    "span_breakdown",
    "spans",
    "trace_to_dict",
    "validate_exposition",
]
