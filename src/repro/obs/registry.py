"""Process-wide metrics registry — counters, gauges, histograms, exporters.

One :class:`MetricsRegistry` per process (the module-level default,
reachable via :func:`registry`) that every subsystem registers into:
``ServeMetrics`` (request/batch/latency), ``GeometryCache`` (hit / miss /
eviction), ``kernels/dispatch`` (per-family resolution counts, autotune
results, achieved GFLOP/s) and the solve front door (per-status solve
counts, rescue and fallback totals). Two exporters read it:

* :meth:`MetricsRegistry.snapshot` — a JSON-safe nested dict (the
  BENCH_*.json contract: ``json.dumps`` round-trips it losslessly), with
  :meth:`write_jsonl` appending one snapshot per line for trajectory
  logging;
* :meth:`MetricsRegistry.prometheus_text` — Prometheus text exposition
  format (0.0.4), served by ``GWServer.metrics_text()`` and
  ``launch/serve.py --metrics-port``.

Histograms keep a bounded :class:`Reservoir` (exact percentiles up to
``DEFAULT_RESERVOIR_CAP`` = 8192 samples, uniform reservoir sampling
past the cap) alongside fixed Prometheus buckets, so both exporters get
faithful tails without unbounded memory.

All metric objects are thread-safe (one lock per metric; the registry
lock only guards creation), and everything here is plain host-side
Python — importing this module never touches a device.
"""
from __future__ import annotations

import json
import math
import random
import re
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

DEFAULT_QS = (50, 95, 99)

# exact percentiles up to this many samples; uniform reservoir beyond
DEFAULT_RESERVOIR_CAP = 8192

# latency-flavored default buckets (seconds) — Prometheus convention,
# +Inf is implicit
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0)

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def percentiles(samples: Sequence[float],
                qs: Sequence[int] = DEFAULT_QS) -> Dict[str, float]:
    """``{"p50": ..., "p95": ..., "p99": ...}`` of ``samples`` (linear
    interpolation; empty input yields NaNs so callers can't mistake "no
    data" for "zero latency")."""
    if len(samples) == 0:
        return {f"p{q}": float("nan") for q in qs}
    arr = np.asarray(list(samples), dtype=np.float64)
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}


class Reservoir:
    """Bounded sample store: exact below ``cap``, uniform sampling after.

    Behaves as a sequence (``len`` / iteration / indexing) over the
    retained samples so it drops into :func:`percentiles` wherever a
    plain list used to be; ``n_seen`` counts every ``add`` ever made.
    Percentiles are exact while ``n_seen <= cap`` and an unbiased
    estimate (Vitter's algorithm R) beyond it.
    """

    def __init__(self, cap: int = DEFAULT_RESERVOIR_CAP, seed: int = 0):
        if cap < 1:
            raise ValueError(f"cap must be >= 1, got {cap}")
        self.cap = cap
        self.n_seen = 0
        self._items: List[float] = []
        self._rng = random.Random(seed)

    def add(self, value: float) -> None:
        self.n_seen += 1
        if len(self._items) < self.cap:
            self._items.append(float(value))
            return
        j = self._rng.randrange(self.n_seen)
        if j < self.cap:
            self._items[j] = float(value)

    append = add        # list-compatible spelling

    def __len__(self) -> int:
        return len(self._items)

    def __iter__(self):
        return iter(self._items)

    def __getitem__(self, i):
        return self._items[i]


# ---------------------------------------------------------------------------
# Metric primitives
# ---------------------------------------------------------------------------

class Counter:
    """Monotone counter."""

    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        with self._lock:
            self.value += amount


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount


class Histogram:
    """Cumulative-bucket histogram + bounded reservoir for exact tails."""

    __slots__ = ("buckets", "bucket_counts", "count", "sum", "reservoir",
                 "_lock")

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS,
                 reservoir_cap: int = DEFAULT_RESERVOIR_CAP):
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self.bucket_counts = [0] * len(self.buckets)
        self.count = 0
        self.sum = 0.0
        self.reservoir = Reservoir(reservoir_cap)
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        value = float(value)
        with self._lock:
            self.count += 1
            self.sum += value
            for k, ub in enumerate(self.buckets):
                if value <= ub:
                    self.bucket_counts[k] += 1
            self.reservoir.add(value)

    def percentiles(self, qs: Sequence[int] = DEFAULT_QS) -> Dict[str, float]:
        with self._lock:
            items = list(self.reservoir)
        return percentiles(items, qs)


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Family:
    """All label-series of one metric name (one TYPE line per family)."""

    def __init__(self, name: str, kind: str, help: str):
        self.name = name
        self.kind = kind
        self.help = help
        self.series: "Dict[Tuple[Tuple[str, str], ...], object]" = {}


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    for k in labels:
        if not _LABEL_RE.match(k):
            raise ValueError(f"invalid label name {k!r}")
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _escape_label(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


def _fmt_labels(key: Tuple[Tuple[str, str], ...], extra: str = "") -> str:
    parts = [f'{k}="{_escape_label(v)}"' for k, v in key]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt_value(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    return repr(float(v))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

class MetricsRegistry:
    """Get-or-create registry of named, optionally labeled metrics."""

    def __init__(self):
        self._families: Dict[str, _Family] = {}
        self._lock = threading.Lock()
        self._t0 = time.time()

    # -- creation -----------------------------------------------------------

    def _get(self, name: str, kind: str, help: str, factory):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = _Family(name, kind, help)
            elif fam.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}, "
                    f"requested {kind}")
            if help and not fam.help:
                fam.help = help
            return fam

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        fam = self._get(name, "counter", help, Counter)
        key = _label_key(labels)
        with self._lock:
            if key not in fam.series:
                fam.series[key] = Counter()
            return fam.series[key]

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        fam = self._get(name, "gauge", help, Gauge)
        key = _label_key(labels)
        with self._lock:
            if key not in fam.series:
                fam.series[key] = Gauge()
            return fam.series[key]

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS,
                  reservoir_cap: int = DEFAULT_RESERVOIR_CAP,
                  **labels) -> Histogram:
        fam = self._get(name, "histogram", help, Histogram)
        key = _label_key(labels)
        with self._lock:
            if key not in fam.series:
                fam.series[key] = Histogram(buckets, reservoir_cap)
            return fam.series[key]

    def clear(self) -> None:
        """Drop every registered metric (tests / fresh measurement runs)."""
        with self._lock:
            self._families.clear()
            self._t0 = time.time()

    # -- exporters ----------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-safe nested dict of every metric (round-trips through
        ``json.dumps``/``loads`` losslessly — NaN-valued gauges are
        exported as ``None``)."""
        def _num(v: float):
            v = float(v)
            return v if math.isfinite(v) else None

        out: dict = {"uptime_s": time.time() - self._t0, "metrics": {}}
        with self._lock:
            families = {n: (f.kind, f.help, dict(f.series))
                        for n, f in self._families.items()}
        for name, (kind, help_, series) in sorted(families.items()):
            rows = []
            for key, metric in sorted(series.items()):
                row: dict = {"labels": {k: v for k, v in key}}
                if kind == "histogram":
                    pcts = metric.percentiles()
                    row.update({
                        "count": metric.count,
                        "sum": _num(metric.sum),
                        "p50": _num(pcts["p50"]),
                        "p95": _num(pcts["p95"]),
                        "p99": _num(pcts["p99"]),
                        "retained": len(metric.reservoir),
                        "n_seen": metric.reservoir.n_seen,
                    })
                else:
                    row["value"] = _num(metric.value)
                rows.append(row)
            out["metrics"][name] = {"type": kind, "help": help_,
                                    "series": rows}
        return out

    def jsonl_line(self, extra: Optional[dict] = None) -> str:
        """One JSON object line: the snapshot plus caller context."""
        doc = self.snapshot()
        doc["ts"] = time.time()
        if extra:
            doc.update(extra)
        return json.dumps(doc)

    def write_jsonl(self, path, extra: Optional[dict] = None) -> None:
        """Append one snapshot line to ``path`` (JSON-lines sink)."""
        with open(path, "a") as f:
            f.write(self.jsonl_line(extra) + "\n")

    def prometheus_text(self) -> str:
        """The registry in Prometheus text exposition format (0.0.4)."""
        lines: List[str] = []
        with self._lock:
            families = {n: (f.kind, f.help, dict(f.series))
                        for n, f in self._families.items()}
        for name, (kind, help_, series) in sorted(families.items()):
            lines.append(f"# HELP {name} {help_ or name}")
            lines.append(f"# TYPE {name} {kind}")
            for key, metric in sorted(series.items()):
                if kind == "histogram":
                    for ub, c in zip(metric.buckets, metric.bucket_counts):
                        le = 'le="%s"' % _fmt_value(ub)
                        lines.append(
                            f"{name}_bucket{_fmt_labels(key, le)} {c}")
                    inf = 'le="+Inf"'
                    lines.append(
                        f"{name}_bucket{_fmt_labels(key, inf)}"
                        f" {metric.count}")
                    lines.append(
                        f"{name}_sum{_fmt_labels(key)}"
                        f" {_fmt_value(metric.sum)}")
                    lines.append(
                        f"{name}_count{_fmt_labels(key)} {metric.count}")
                else:
                    lines.append(f"{name}{_fmt_labels(key)}"
                                 f" {_fmt_value(metric.value)}")
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# Exposition-format validation (tests + the CI obs-smoke job)
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?P<labels>\{[^{}]*\})?"
    r"\s+(?P<value>[+-]?(?:\d+\.?\d*(?:[eE][+-]?\d+)?|Inf|NaN))"
    r"(?:\s+[+-]?\d+)?$")
_LABELPAIR_RE = re.compile(
    r'^[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"$')


def validate_exposition(text: str) -> int:
    """Validate Prometheus text exposition format; returns the sample
    count. Raises ``ValueError`` on the first malformed line."""
    if not text.endswith("\n"):
        raise ValueError("exposition text must end with a newline")
    n_samples = 0
    for lineno, line in enumerate(text.split("\n"), start=1):
        if not line:
            continue
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            parts = line.split(None, 3)
            if len(parts) < 3 or not _NAME_RE.match(parts[2]):
                raise ValueError(f"line {lineno}: bad comment: {line!r}")
            if parts[1] == "TYPE" and parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                raise ValueError(f"line {lineno}: bad TYPE: {line!r}")
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: bad sample: {line!r}")
        labels = m.group("labels")
        if labels:
            body = labels[1:-1]
            if body:
                for pair in re.split(r',(?=[a-zA-Z_])', body):
                    if pair and not _LABELPAIR_RE.match(pair):
                        raise ValueError(
                            f"line {lineno}: bad label {pair!r}")
        n_samples += 1
    return n_samples


# ---------------------------------------------------------------------------
# Process-wide default
# ---------------------------------------------------------------------------

_GLOBAL = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-wide default registry every subsystem shares."""
    return _GLOBAL
