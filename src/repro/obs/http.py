"""Minimal Prometheus scrape endpoint for the process registry.

``launch/serve.py --metrics-port 9100`` (or any caller) starts a daemon
``ThreadingHTTPServer`` whose ``/metrics`` route returns
``registry().prometheus_text()``; everything else is 404. The thread
never blocks process exit.
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.obs.registry import MetricsRegistry, registry

_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def serve_metrics_http(port: int, host: str = "127.0.0.1",
                       reg: Optional[MetricsRegistry] = None
                       ) -> ThreadingHTTPServer:
    """Serve ``/metrics`` on ``host:port`` from a daemon thread.

    Returns the server object (``.server_address`` carries the bound
    port — useful with ``port=0``; call ``.shutdown()`` to stop it).
    """
    the_reg = reg if reg is not None else registry()

    class _Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 — http.server API
            if self.path.split("?")[0] not in ("/metrics", "/"):
                self.send_error(404)
                return
            body = the_reg.prometheus_text().encode("utf-8")
            self.send_response(200)
            self.send_header("Content-Type", _CONTENT_TYPE)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):  # silence per-request stderr noise
            pass

    server = ThreadingHTTPServer((host, port), _Handler)
    thread = threading.Thread(target=server.serve_forever,
                              name="repro-metrics-http", daemon=True)
    thread.start()
    return server
