"""In-jit convergence traces — fixed-size per-iteration buffers.

A :class:`ConvergenceTrace` is a pytree of ``(max_iters,)`` buffers that
rides through ``health/loop.health_loop`` as part of the ``while_loop``
carry, recording per-outer-iteration:

``err``        marginal violation (the loop's convergence criterion)
``objective``  solver objective value (present when the solver supplies
               an ``obj_fn``; NaN-filled otherwise)
``delta``      relative iterate movement ‖T_new − T‖₁ / ‖T‖₁
``mass``       total transported mass ‖T‖₁ after the step
``scale``      ε-rescue step scale in effect (``rescue_factor**n_rescues``)
``rescued``    1.0 at iterations where an ε-rescue restart fired

Because it is a NamedTuple of arrays it is automatically a pytree: it
vmaps (one independent trace per lane — the health layer's ``where``
masking keeps a poisoned lane's rescue events out of its peers), jits,
and lands on :class:`~repro.api.output.GWOutput` as ``out.trace``.

Entries past ``n_iters`` keep their NaN fill: the trace length *is*
``n_iters`` (``scale`` is written at every consumed iteration and is
always finite, so its non-NaN prefix counts iterations; ``mass`` may
legitimately hold inf/NaN *inside* the prefix — it records the unhealthy
value that triggered a rescue).
Tracing is opt-in (``solver.trace=True``); when off the trace is
``None`` — zero extra pytree leaves and bitwise-identical outputs.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np


class ConvergenceTrace(NamedTuple):
    """Per-outer-iteration history of one solve (or one vmap lane)."""
    err: Any          # (max_iters,) marginal violation per iteration
    objective: Any    # (max_iters,) objective value (NaN if no obj_fn)
    delta: Any        # (max_iters,) relative L1 movement of the iterate
    mass: Any         # (max_iters,) total mass ||T||_1 after the step
    scale: Any        # (max_iters,) rescue step scale in effect
    rescued: Any      # (max_iters,) 1.0 where an eps-rescue fired


def empty_trace(max_iters: int, dtype=jnp.float32) -> ConvergenceTrace:
    """NaN-filled trace buffers for a loop of at most ``max_iters``."""
    nan = jnp.full((max_iters,), jnp.nan, dtype=dtype)
    return ConvergenceTrace(err=nan, objective=nan, delta=nan, mass=nan,
                            scale=nan, rescued=nan)


def n_valid(trace: ConvergenceTrace) -> int:
    """Number of recorded iterations (non-NaN prefix of ``scale``)."""
    return int(np.sum(np.isfinite(np.asarray(trace.scale))))


def trace_to_dict(trace: Optional[ConvergenceTrace],
                  n_iters: Optional[int] = None) -> Optional[dict]:
    """JSON-safe dict of the trace, trimmed to the recorded prefix.

    ``n_iters`` trims explicitly; otherwise the non-NaN prefix of
    ``scale`` is used. Non-finite values inside the prefix (e.g.
    ``objective`` with no ``obj_fn``, or the exploded ``mass`` at a
    rescue iteration) become ``None`` so the result survives strict JSON.
    """
    if trace is None:
        return None
    n = int(n_iters) if n_iters is not None else n_valid(trace)

    def _col(x):
        vals = np.asarray(x)[:n].astype(np.float64)
        return [float(v) if np.isfinite(v) else None for v in vals]

    return {
        "n_iters": n,
        "err": _col(trace.err),
        "objective": _col(trace.objective),
        "delta": _col(trace.delta),
        "mass": _col(trace.mass),
        "scale": _col(trace.scale),
        "rescued": _col(trace.rescued),
    }
