"""``obs.report()`` — one JSON document tying the telemetry together.

After a traced solve::

    solver = dataclasses.replace(get_solver("spar_gw").default_config(n),
                                 trace=True)
    out = repro.solve(problem, solver, key=key)
    doc = repro.obs.report(out)

``doc`` is JSON-serializable and carries:

``solve``    the outcome (value, n_iters, status, rescues) plus the full
             per-iteration convergence trace (trimmed to ``n_iters``)
``spans``    every completed lifecycle span, in start order
``breakdown``per-stage aggregate (count, total_s) with the headline
             ``compile_s`` / ``dispatch_s`` / ``rescue_s`` /
             ``fallback_s`` splits derived from the span names
``metrics``  a snapshot of the process-wide registry

``repro.solve`` calls :func:`note_solve` on every concrete output, so
``report()`` with no argument describes the most recent solve.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Optional

import numpy as np

from repro.obs.registry import registry
from repro.obs.span import span_breakdown, spans
from repro.obs.trace import trace_to_dict

_last_solve: Optional[dict] = None


def _solve_section(out: Any, solver: Optional[str] = None) -> dict:
    sec: Dict[str, Any] = {"solver": solver}
    v = np.asarray(out.value)
    sec["value"] = float(v) if v.ndim == 0 else v.astype(float).tolist()
    n_iters = int(np.asarray(out.n_iters))
    sec["n_iters"] = n_iters
    sec["converged"] = bool(np.asarray(out.converged))
    status = getattr(out, "status", None)
    if status is not None:
        sec["status"] = status.describe()
        sec["n_rescues"] = int(np.asarray(status.n_rescues))
    sec["trace"] = trace_to_dict(getattr(out, "trace", None), n_iters)
    return sec


def note_solve(out: Any, solver: Optional[str] = None) -> None:
    """Stash a completed (concrete) solve for argument-less report()."""
    global _last_solve
    try:
        _last_solve = _solve_section(out, solver)
    except Exception:  # noqa: BLE001 — reporting must never break a solve
        _last_solve = None


def report(out: Any = None, solver: Optional[str] = None) -> dict:
    """One JSON document: solve outcome + trace + spans + metrics."""
    if out is not None:
        solve_sec: Optional[dict] = _solve_section(out, solver)
    else:
        solve_sec = _last_solve
    records = spans()
    agg = span_breakdown(records)

    def _total(*names: str) -> float:
        return sum(agg[n]["total_s"] for n in names if n in agg)

    # dispatches that triggered an XLA compilation carry compiled=True —
    # their wall-clock is compile time, not steady-state dispatch
    compile_s = _total("bench.compile") + sum(
        r["duration_s"] for r in records
        if r["name"] in ("solve.dispatch", "serve.dispatch")
        and r.get("compiled"))
    dispatch_s = sum(
        r["duration_s"] for r in records
        if r["name"] in ("solve.dispatch", "serve.dispatch")
        and not r.get("compiled"))
    breakdown = {
        "by_name": agg,
        "compile_s": compile_s,
        "dispatch_s": dispatch_s,
        "rescue_s": _total("solve.rescue"),
        "fallback_s": _total("solve.fallback", "serve.fallback"),
    }
    doc = {
        "solve": solve_sec,
        "spans": records,
        "breakdown": breakdown,
        "metrics": registry().snapshot(),
    }
    # the contract is "one JSON document" — fail here, not in the caller
    json.dumps(doc)
    return doc
