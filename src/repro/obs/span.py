"""Solve-lifecycle spans — lightweight host-side timing scopes.

A span is a named wall-clock interval::

    from repro import obs

    with obs.span("compile"):
        executable = lowered.compile()

Spans nest (the active stack is thread-local, so concurrent server
threads never corrupt each other's nesting) and each completed span is
appended to one process-wide bounded ring, which :func:`spans` snapshots
and :func:`repro.obs.report` aggregates into the per-stage lifecycle
breakdown (select → validate → compile → dispatch → fallback).

The record a span yields is a plain dict — callers may attach attributes
mid-flight (``with span("dispatch") as sp: ...; sp["compiled"] = True``),
which is how ``repro.solve`` marks the dispatches that triggered an XLA
compilation.

With ``REPRO_OBS_XLA=1`` (or ``configure(xla_annotations=True)``) every
span also enters a ``jax.profiler.TraceAnnotation`` of the same name, so
host-side spans land as named regions in XLA profiler traces with zero
changes at the call sites.

Overhead per span is two ``perf_counter`` calls plus one deque append
(~1 µs) — safe on the serving hot path.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional

_TRUTHY = {"1", "true", "yes", "on"}

# bounded: a long-lived server must not grow span history without limit
MAX_SPANS = 65536

_T0 = time.perf_counter()        # process-relative clock zero
_lock = threading.Lock()
_records: "deque[dict]" = deque(maxlen=MAX_SPANS)
_tls = threading.local()

# None = resolve from the REPRO_OBS_XLA env var at span entry
_xla_annotations: Optional[bool] = None


def configure(xla_annotations: Optional[bool] = None) -> None:
    """Set the XLA-annotation pass-through (None = defer to env)."""
    global _xla_annotations
    _xla_annotations = xla_annotations


def _use_xla() -> bool:
    if _xla_annotations is not None:
        return _xla_annotations
    return os.environ.get("REPRO_OBS_XLA", "").strip().lower() in _TRUTHY


def _stack() -> List[dict]:
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    return stack


@contextmanager
def span(name: str, **attrs) -> Iterator[dict]:
    """Record a named wall-clock span; yields its (mutable) record dict.

    Extra keyword arguments become attributes of the record; more can be
    attached to the yielded dict before the block exits. Records carry
    ``name`` / ``start_s`` (process-relative) / ``duration_s`` /
    ``depth`` / ``parent`` / ``thread``.
    """
    stack = _stack()
    rec: Dict = {
        "name": name,
        "start_s": time.perf_counter() - _T0,
        "duration_s": 0.0,
        "depth": len(stack),
        "parent": stack[-1]["name"] if stack else None,
        "thread": threading.current_thread().name,
    }
    rec.update(attrs)
    stack.append(rec)
    ann = None
    if _use_xla():
        try:
            import jax
            ann = jax.profiler.TraceAnnotation(name)
            ann.__enter__()
        except Exception:  # noqa: BLE001 — profiling must never break a solve
            ann = None
    t_in = time.perf_counter()
    try:
        yield rec
    finally:
        rec["duration_s"] = time.perf_counter() - t_in
        if ann is not None:
            try:
                ann.__exit__(None, None, None)
            except Exception:  # noqa: BLE001
                pass
        stack.pop()
        with _lock:
            _records.append(rec)


def spans() -> List[dict]:
    """Snapshot of completed span records, ordered by start time.

    (Completion order interleaves children before parents; sorting by
    ``start_s`` restores the lifecycle order a reader expects.)
    """
    with _lock:
        out = [dict(r) for r in _records]
    return sorted(out, key=lambda r: r["start_s"])


def clear_spans() -> None:
    with _lock:
        _records.clear()


def span_breakdown(records: Optional[List[dict]] = None) -> Dict[str, dict]:
    """Aggregate span durations by name: ``{name: {count, total_s}}``."""
    if records is None:
        records = spans()
    agg: Dict[str, dict] = {}
    for r in records:
        slot = agg.setdefault(r["name"], {"count": 0, "total_s": 0.0})
        slot["count"] += 1
        slot["total_s"] += r["duration_s"]
    return agg
