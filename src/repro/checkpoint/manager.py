"""Fault-tolerant checkpointing: atomic, async, keep-k, sharding-agnostic.

Format: one ``.npy`` per pytree leaf + a JSON manifest (tree structure,
shapes, dtypes, data-pipeline state). Writes go to ``<step>.tmp`` and are
renamed only when complete — a crashed writer can never produce a
checkpoint that ``latest_step`` will pick up (restart safety).

Checkpoints store *unsharded* arrays with no mesh metadata, so restores can
re-shard onto a different mesh/device count (elastic re-scaling): pass
``shardings`` to ``restore`` and each leaf is ``device_put`` with its new
NamedSharding. Multi-host note: at pod scale the same manifest format is
written per-shard with a process-0 barrier; the atomic-rename + manifest
protocol is what is exercised here.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             blocking: bool = True):
        """Atomic checkpoint write; ``blocking=False`` runs in a background
        thread (compute continues while the previous step persists)."""
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if blocking:
            self._write(step, host_tree, extra or {})
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, extra or {}))
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree, extra):
        tmp = self.dir / f"step_{step:010d}.tmp"
        final = self.dir / f"step_{step:010d}"
        if final.exists() and (final / "manifest.json").exists():
            return                       # checkpoints are immutable
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves, _ = _flatten_with_paths(host_tree)
        manifest = {"step": step, "extra": extra, "leaves": []}
        for key, leaf in leaves:
            fname = key.replace("/", "__") + ".npy"
            np.save(tmp / fname, leaf)
            manifest["leaves"].append(
                {"key": key, "file": fname, "shape": list(leaf.shape),
                 "dtype": str(leaf.dtype)})
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, final)          # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree: Any, shardings: Any = None):
        """Restore into the structure of ``target_tree``. ``shardings``
        (optional pytree of NamedSharding) re-shards every leaf onto the
        current mesh — elastic restore onto a different topology."""
        path = self.dir / f"step_{step:010d}"
        with open(path / "manifest.json") as f:
            manifest = json.load(f)
        by_key = {l["key"]: l for l in manifest["leaves"]}
        leaves, treedef = _flatten_with_paths(target_tree)
        sh_leaves = None
        if shardings is not None:
            sh_leaves = [s for _, s in _flatten_with_paths(shardings)[0]]
        out = []
        for i, (key, leaf) in enumerate(leaves):
            rec = by_key[key]
            arr = np.load(path / rec["file"])
            if sh_leaves is not None:
                arr = jax.device_put(arr, sh_leaves[i])
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
