"""Pytree registration for the API dataclasses.

The API dataclasses validate their inputs in ``__init__``. JAX
transformations unflatten pytrees with tracers (and occasionally with
sentinel objects that have no ``.shape``), so unflattening must *never*
re-run the constructor. ``register_pytree_dataclass`` therefore installs a
flatten/unflatten pair that rebuilds instances with ``object.__new__`` +
``setattr``, bypassing ``__init__``/``__post_init__`` entirely.

``data_fields`` become pytree leaves (traced, batched, donated, ...);
``meta_fields`` become hashable aux data (part of the tree structure, so a
change in a meta field retraces jitted callees — use them for knobs that
select code paths).
"""
from __future__ import annotations

from typing import Sequence

import jax


def register_pytree_dataclass(cls, data_fields: Sequence[str],
                              meta_fields: Sequence[str] = ()):
    data_fields = tuple(data_fields)
    meta_fields = tuple(meta_fields)

    def flatten(obj):
        return (tuple(getattr(obj, f) for f in data_fields),
                tuple(getattr(obj, f) for f in meta_fields))

    def unflatten(meta, data):
        obj = object.__new__(cls)
        for f, v in zip(data_fields, data):
            object.__setattr__(obj, f, v)
        for f, v in zip(meta_fields, meta):
            object.__setattr__(obj, f, v)
        return obj

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


def is_concrete(x) -> bool:
    """True when ``x`` carries a concrete value (not a JAX tracer)."""
    return not isinstance(x, jax.core.Tracer)
