"""Unified Problem/Solver/Output API (DESIGN.md §"API layer").

Pytree-native layer over the paper's solver family: build a
``QuadraticProblem`` from two ``Geometry``s, pick a solver config (or a
registry name, or let ``select_solver`` pick one from the problem
structure), and call ``repro.solve`` — every variant (GW, entropic,
fused, unbalanced, sparse, grid, multiscale, low-rank) returns the same
structured ``GWOutput`` and composes with ``jax.jit`` / ``jax.vmap``.
"""
from repro.api.geometry import Geometry
from repro.api.output import (
    GridCoupling,
    GWOutput,
    LowRankCoupling,
    QuantizedCoupling,
    SparseCoupling,
)
from repro.api.problem import QuadraticProblem
from repro.api.solve import select_solver, solve
from repro.api.solvers import (
    DenseGWSolver,
    GridGWSolver,
    SparGWSolver,
    available_solvers,
    get_solver,
    register_solver,
)

# importing the multiscale / lowrank subsystems registers the
# "quantized_gw" / "lowrank_gw" solvers
from repro.multiscale.solver import QuantizedGWSolver  # noqa: E402
from repro.lowrank.solver import LowRankGWSolver  # noqa: E402

__all__ = [
    "Geometry",
    "QuadraticProblem",
    "GWOutput",
    "SparseCoupling",
    "GridCoupling",
    "QuantizedCoupling",
    "LowRankCoupling",
    "solve",
    "select_solver",
    "SparGWSolver",
    "DenseGWSolver",
    "GridGWSolver",
    "QuantizedGWSolver",
    "LowRankGWSolver",
    "get_solver",
    "register_solver",
    "available_solvers",
]
