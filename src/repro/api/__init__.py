"""Unified Problem/Solver/Output API (DESIGN.md §"API layer").

Pytree-native layer over the paper's solver family: build a
``QuadraticProblem`` from two ``Geometry``s, pick a solver config (or a
registry name), and call ``repro.solve`` — every variant (GW, entropic,
fused, unbalanced, sparse, grid) returns the same structured ``GWOutput``
and composes with ``jax.jit`` / ``jax.vmap``.
"""
from repro.api.geometry import Geometry
from repro.api.output import GridCoupling, GWOutput, SparseCoupling
from repro.api.problem import QuadraticProblem
from repro.api.solve import solve
from repro.api.solvers import (
    DenseGWSolver,
    GridGWSolver,
    SparGWSolver,
    available_solvers,
    get_solver,
    register_solver,
)

__all__ = [
    "Geometry",
    "QuadraticProblem",
    "GWOutput",
    "SparseCoupling",
    "GridCoupling",
    "solve",
    "SparGWSolver",
    "DenseGWSolver",
    "GridGWSolver",
    "get_solver",
    "register_solver",
    "available_solvers",
]
