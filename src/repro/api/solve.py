"""``repro.solve`` — the single front door for every GW variant.

    out = repro.solve(problem, solver=SparGWSolver(s=16 * n), key=key)

``problem`` and ``solver`` are pytrees and the call is jitted internally,
so repeated solves with the same structure (shapes + static knobs) reuse
the compiled executable, and the whole call nests under user ``jax.jit``
and ``jax.vmap`` transforms — batching a stack of problems over keys is

    batched = jax.vmap(lambda p, k: repro.solve(p, solver=s, key=k))
    out = batched(stacked_problems, jax.random.split(key, B))

where ``stacked_problems = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)``.
"""
from __future__ import annotations

from typing import Optional, Union

import jax

from repro.api.problem import QuadraticProblem
from repro.api.solvers import get_solver


@jax.jit
def _solve_jit(problem, solver, key):
    return solver.run(problem, key)


def solve(problem: QuadraticProblem, solver: Union[str, object] = "spar_gw",
          key: Optional[jax.Array] = None, validate: bool = True):
    """Solve a QuadraticProblem; returns a structured ``GWOutput``.

    solver   — a solver config instance, or a registry name ("spar_gw",
               "dense_gw", "grid_gw", ...) which selects that solver's
               ``default_config`` for the problem size
    key      — PRNG key; required by sampling solvers, ignored by dense
    validate — run the problem's boundary checks if they haven't run yet
               (construction with validate=True already marks the problem
               validated; value checks are auto-skipped under tracing;
               pass False for zero overhead)
    """
    if isinstance(solver, str):
        solver = get_solver(solver).default_config(problem.geom_x.n)
    if validate and not getattr(problem, "_validated", False):
        problem.check()
    return _solve_jit(problem, solver, key)
