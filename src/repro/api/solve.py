"""``repro.solve`` — the single front door for every GW variant.

    out = repro.solve(problem, solver=SparGWSolver(s=16 * n), key=key)

``problem`` and ``solver`` are pytrees and the call is jitted internally,
so repeated solves with the same structure (shapes + static knobs) reuse
the compiled executable, and the whole call nests under user ``jax.jit``
and ``jax.vmap`` transforms — batching a stack of problems over keys is

    batched = jax.vmap(lambda p, k: repro.solve(p, solver=s, key=k))
    out = batched(stacked_problems, jax.random.split(key, B))

where ``stacked_problems = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)``.

With ``solver=None`` (the default) a solver is auto-selected from the
problem's structure — see :func:`select_solver`.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import numpy as np

from repro.api.problem import QuadraticProblem
from repro.api.pytree import is_concrete
from repro.api.solvers import get_solver
from repro.health.fallback import fallback_chain
from repro.health.status import DIVERGED, STALLED, SolveDivergedError
from repro.obs.registry import registry
from repro.obs.report import note_solve
from repro.obs.span import span

# auto-selection size thresholds (max(m, n)); see select_solver
AUTO_DENSE_MAX = 256
AUTO_SPAR_MAX = 2048
# above this, even the multiscale pipeline's quadratic stages (anchor
# compression, O(m²k) matmuls) dominate — route to the linear-time
# low-rank solver whenever the problem admits it
_LOWRANK_MIN = 8192

# ground losses with a Peyré decomposition L = f1 + f2 - h1·h2 (the
# structure the low-rank gradient factorization needs)
_LOWRANK_LOSSES = ("l2", "kl")


def _lowrank_eligible(problem: QuadraticProblem) -> bool:
    """lowrank_gw handles balanced, non-fused, decomposable-loss problems."""
    return (not problem.is_fused and not problem.is_unbalanced
            and problem.loss in _LOWRANK_LOSSES)


def select_solver(problem: QuadraticProblem):
    """Pick a solver config from the problem's structure (size/variant).

    Heuristic (ROADMAP "solver auto-selection"):

    * max(m, n) ≤ 256 — ``dense_gw``: full-resolution PGA is cheap, exact
      resolution, and needs no PRNG key;
    * ≤ 2048 — ``spar_gw`` with the paper's s = 16n support: the O(s²)
      cost assembly still beats dense O(n³)-per-iteration work;
    * larger — ``lowrank_gw`` when the problem admits it (balanced,
      non-fused, decomposable loss) **and** either both geometries are
      point clouds (exact rank-(d+2) cost factors, zero n×n work) or
      max(m, n) exceeds ``_LOWRANK_MIN`` (where even the multiscale
      pipeline's quadratic compression stage dominates and the rank-c
      sketch pays for itself); otherwise ``quantized_gw`` (multiscale),
      which covers fused/unbalanced/indecomposable structure at any
      scale. (For unbalanced problems at this scale the reported value
      is the anchor-level estimate and the refined marginals are
      relaxed — but spar_gw's O((16n)²)-per-iteration assembly is
      infeasible there, so quantized is still the right default.)
    """
    size = max(problem.shape)
    if size <= AUTO_DENSE_MAX:
        return get_solver("dense_gw").default_config(size)
    if size <= AUTO_SPAR_MAX:
        return get_solver("spar_gw").default_config(size)
    # the point-cloud fast route requires the *exact* factorization path
    # (squared-euclidean + l2), which never materializes an n×n matrix;
    # kl point clouds would silently densify for the sketch, so they wait
    # for the _LOWRANK_MIN threshold like precomputed costs
    factorizable = (problem.geom_x.is_point_cloud
                    and problem.geom_y.is_point_cloud
                    and problem.loss == "l2")
    if _lowrank_eligible(problem) and (factorizable
                                       or size > _LOWRANK_MIN):
        return get_solver("lowrank_gw").default_config(size)
    return get_solver("quantized_gw").default_config(size)


@jax.jit
def _solve_jit(problem, solver, key):
    return solver.run(problem, key)


def _jit_cache_size() -> int:
    """Entry count of ``_solve_jit``'s executable cache (-1 if the JAX
    version doesn't expose it) — a dispatch that grows it compiled."""
    try:
        return _solve_jit._cache_size()
    except Exception:  # noqa: BLE001 — observability only
        return -1


def _dispatch(problem, solver, key, solver_name: str):
    """One jitted dispatch under a ``solve.dispatch`` span, marking the
    calls that triggered an XLA compilation (``compiled=True``) so the
    lifecycle breakdown can split compile_s from steady dispatch_s."""
    before = _jit_cache_size()
    with span("solve.dispatch", solver=solver_name) as sp:
        out = _solve_jit(problem, solver, key)
        sp["compiled"] = bool(before >= 0 and _jit_cache_size() > before)
    return out


def _record_outcome(solver_name: str, out, fell_back: bool = False) -> None:
    """Registry counters for a solve whose status is already concrete.

    Only called from paths that have inspected the output on the host
    (``on_failure != 'none'``) — counting earlier would force a device
    sync and defeat async dispatch.
    """
    try:
        reg = registry()
        status_name = ("UNKNOWN" if out.status is None
                       else out.status.describe())
        reg.counter("repro_solves_total", "completed solves by status",
                    solver=solver_name, status=status_name).inc()
        if out.status is not None:
            reg.counter("repro_rescues_total",
                        "in-jit eps-rescue restarts consumed",
                        solver=solver_name).inc(
                            float(np.sum(np.asarray(out.status.n_rescues))))
        note_solve(out, solver=solver_name)
    except Exception:  # noqa: BLE001 — telemetry must never break a solve
        pass


def _solve_failed(out) -> bool:
    """Host-side failure predicate: DIVERGED/STALLED status (any lane) or
    a non-finite value."""
    if out.status is not None and bool(np.any(
            np.asarray(out.status.code) >= STALLED)):
        return True
    return not bool(np.all(np.isfinite(np.asarray(out.value))))


def solve(problem: QuadraticProblem,
          solver: Union[str, object, None] = None,
          key: Optional[jax.Array] = None, validate: bool = True,
          on_failure: str = "none"):
    """Solve a QuadraticProblem; returns a structured ``GWOutput``.

    solver     — a solver config instance; a registry name ("spar_gw",
                 "dense_gw", "grid_gw", "quantized_gw", "lowrank_gw", ...)
                 which selects
                 that solver's ``default_config`` for the problem size; or
                 None to auto-select from the problem structure
                 (:func:`select_solver`)
    key        — PRNG key; required by sampling/multiscale solvers, ignored
                 by dense (checked here, eagerly, so a missing key is a
                 clear ``ValueError`` instead of a mid-trace failure)
    validate   — run the problem's boundary checks if they haven't run yet
                 (construction with validate=True already marks the problem
                 validated; value checks are auto-skipped under tracing;
                 pass False for zero overhead)
    on_failure — what to do when the solve comes back unhealthy (DIVERGED
                 or STALLED status after the solver's own in-jit ε-rescue
                 budget, or a non-finite value):
                 * "none" (default) — return the output as-is; inspect
                   ``out.status`` yourself
                 * "raise" — raise :class:`SolveDivergedError` (the failed
                   output rides on ``.output``)
                 * "fallback" — walk the solver ladder (lowrank →
                   quantized → spar → dense, eligibility-gated; see
                   health/fallback.py), re-keying each attempt with
                   ``jax.random.fold_in(key, attempt)``; returns the first
                   healthy result, or the original failed output if every
                   rung fails.
                 "raise"/"fallback" need concrete outputs, so they are
                 unavailable inside ``jit``/``vmap`` (statuses are traced
                 there — handle failure at the call site instead).
    """
    if on_failure not in ("none", "raise", "fallback"):
        raise ValueError(
            f"on_failure must be 'none', 'raise' or 'fallback', got "
            f"{on_failure!r}")
    with span("solve", on_failure=on_failure) as sp_solve:
        if solver is None:
            with span("solve.select"):
                solver = select_solver(problem)
        elif isinstance(solver, str):
            solver = get_solver(solver).default_config(max(problem.shape))
        primary_name = getattr(type(solver), "name", type(solver).__name__)
        sp_solve["solver"] = primary_name
        if key is None and getattr(type(solver), "requires_key", False):
            raise ValueError(
                f"{type(solver).__name__} needs a PRNG key (it draws a "
                f"random support / anchors / init): call repro.solve("
                f"problem, solver, key=jax.random.PRNGKey(seed))")
        if validate and not getattr(problem, "_validated", False):
            with span("solve.validate"):
                problem.check()
        out = _dispatch(problem, solver, key, primary_name)
        if on_failure == "none":
            # async contract: the output may still be device futures —
            # no host-side status inspection or counting here
            return out
        if not (is_concrete(out.value)
                and (out.status is None or is_concrete(out.status.code))):
            raise ValueError(
                "on_failure='raise'/'fallback' inspects concrete solve "
                "results and cannot run under jit/vmap tracing; call solve "
                "eagerly or use on_failure='none' and handle out.status "
                "downstream")
        failed = _solve_failed(out)
        _record_outcome(primary_name, out)
        if not failed:
            return out
        registry().counter("repro_solve_failures_total",
                           "solves unhealthy after in-jit rescue",
                           solver=primary_name).inc()
        if on_failure == "raise":
            raise SolveDivergedError(
                f"{primary_name} failed: status="
                f"{out.status.describe() if out.status is not None else None}"
                f", value={np.asarray(out.value)}", output=out)
        # fallback: deterministic ladder walk — attempt k re-keys with
        # fold_in(key, k), so recovered solves are bitwise reproducible
        with span("solve.fallback", solver=primary_name) as sp_fb:
            sp_fb["recovered"] = False
            for attempt, cand in enumerate(
                    fallback_chain(problem, exclude=(primary_name,),
                                   key_available=key is not None), start=1):
                cand_name = getattr(type(cand), "name", type(cand).__name__)
                registry().counter("repro_fallback_attempts_total",
                                   "solver-ladder rungs tried",
                                   solver=cand_name).inc()
                cand_key = (None if key is None
                            else jax.random.fold_in(key, attempt))
                cand_out = _dispatch(problem, cand, cand_key, cand_name)
                if not _solve_failed(cand_out):
                    sp_fb["recovered"] = True
                    sp_fb["recovered_by"] = cand_name
                    registry().counter("repro_fallback_recoveries_total",
                                       "failed solves rescued by the ladder",
                                       solver=cand_name).inc()
                    _record_outcome(cand_name, cand_out, fell_back=True)
                    return cand_out
        return out
