"""``repro.solve`` — the single front door for every GW variant.

    out = repro.solve(problem, solver=SparGWSolver(s=16 * n), key=key)

``problem`` and ``solver`` are pytrees and the call is jitted internally,
so repeated solves with the same structure (shapes + static knobs) reuse
the compiled executable, and the whole call nests under user ``jax.jit``
and ``jax.vmap`` transforms — batching a stack of problems over keys is

    batched = jax.vmap(lambda p, k: repro.solve(p, solver=s, key=k))
    out = batched(stacked_problems, jax.random.split(key, B))

where ``stacked_problems = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)``.

With ``solver=None`` (the default) a solver is auto-selected from the
problem's structure — see :func:`select_solver`.
"""
from __future__ import annotations

from typing import Optional, Union

import jax

from repro.api.problem import QuadraticProblem
from repro.api.solvers import get_solver

# auto-selection size thresholds (max(m, n)); see select_solver
AUTO_DENSE_MAX = 256
AUTO_SPAR_MAX = 2048


def select_solver(problem: QuadraticProblem):
    """Pick a solver config from the problem's structure (size/variant).

    Heuristic (ROADMAP "solver auto-selection"):

    * max(m, n) ≤ 256 — ``dense_gw``: full-resolution PGA is cheap, exact
      resolution, and needs no PRNG key;
    * ≤ 2048 — ``spar_gw`` with the paper's s = 16n support: the O(s²)
      cost assembly still beats dense O(n³)-per-iteration work;
    * larger — ``quantized_gw`` (multiscale): the only variant whose
      per-iteration cost does not grow with a power of n. (For
      unbalanced problems at this scale the reported value is the
      anchor-level estimate and the refined marginals are relaxed —
      but spar_gw's O((16n)²)-per-iteration assembly is infeasible
      there, so quantized is still the right default.)

    Fused/unbalanced structure needs no routing beyond that — every
    selected solver dispatches on problem structure internally.
    """
    size = max(problem.shape)
    if size <= AUTO_DENSE_MAX:
        return get_solver("dense_gw").default_config(size)
    if size <= AUTO_SPAR_MAX:
        return get_solver("spar_gw").default_config(size)
    return get_solver("quantized_gw").default_config(size)


@jax.jit
def _solve_jit(problem, solver, key):
    return solver.run(problem, key)


def solve(problem: QuadraticProblem,
          solver: Union[str, object, None] = None,
          key: Optional[jax.Array] = None, validate: bool = True):
    """Solve a QuadraticProblem; returns a structured ``GWOutput``.

    solver   — a solver config instance; a registry name ("spar_gw",
               "dense_gw", "grid_gw", "quantized_gw", ...) which selects
               that solver's ``default_config`` for the problem size; or
               None to auto-select from the problem structure
               (:func:`select_solver`)
    key      — PRNG key; required by sampling/multiscale solvers, ignored
               by dense
    validate — run the problem's boundary checks if they haven't run yet
               (construction with validate=True already marks the problem
               validated; value checks are auto-skipped under tracing;
               pass False for zero overhead)
    """
    if solver is None:
        solver = select_solver(problem)
    elif isinstance(solver, str):
        solver = get_solver(solver).default_config(max(problem.shape))
    if validate and not getattr(problem, "_validated", False):
        problem.check()
    return _solve_jit(problem, solver, key)
