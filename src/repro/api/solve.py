"""``repro.solve`` — the single front door for every GW variant.

    out = repro.solve(problem, solver=SparGWSolver(s=16 * n), key=key)

``problem`` and ``solver`` are pytrees and the call is jitted internally,
so repeated solves with the same structure (shapes + static knobs) reuse
the compiled executable, and the whole call nests under user ``jax.jit``
and ``jax.vmap`` transforms — batching a stack of problems over keys is

    batched = jax.vmap(lambda p, k: repro.solve(p, solver=s, key=k))
    out = batched(stacked_problems, jax.random.split(key, B))

where ``stacked_problems = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)``.

With ``solver=None`` (the default) a solver is auto-selected from the
problem's structure — see :func:`select_solver`.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import numpy as np

from repro.api.problem import QuadraticProblem
from repro.api.pytree import is_concrete
from repro.api.solvers import get_solver
from repro.health.fallback import fallback_chain
from repro.health.status import DIVERGED, STALLED, SolveDivergedError

# auto-selection size thresholds (max(m, n)); see select_solver
AUTO_DENSE_MAX = 256
AUTO_SPAR_MAX = 2048
# above this, even the multiscale pipeline's quadratic stages (anchor
# compression, O(m²k) matmuls) dominate — route to the linear-time
# low-rank solver whenever the problem admits it
_LOWRANK_MIN = 8192

# ground losses with a Peyré decomposition L = f1 + f2 - h1·h2 (the
# structure the low-rank gradient factorization needs)
_LOWRANK_LOSSES = ("l2", "kl")


def _lowrank_eligible(problem: QuadraticProblem) -> bool:
    """lowrank_gw handles balanced, non-fused, decomposable-loss problems."""
    return (not problem.is_fused and not problem.is_unbalanced
            and problem.loss in _LOWRANK_LOSSES)


def select_solver(problem: QuadraticProblem):
    """Pick a solver config from the problem's structure (size/variant).

    Heuristic (ROADMAP "solver auto-selection"):

    * max(m, n) ≤ 256 — ``dense_gw``: full-resolution PGA is cheap, exact
      resolution, and needs no PRNG key;
    * ≤ 2048 — ``spar_gw`` with the paper's s = 16n support: the O(s²)
      cost assembly still beats dense O(n³)-per-iteration work;
    * larger — ``lowrank_gw`` when the problem admits it (balanced,
      non-fused, decomposable loss) **and** either both geometries are
      point clouds (exact rank-(d+2) cost factors, zero n×n work) or
      max(m, n) exceeds ``_LOWRANK_MIN`` (where even the multiscale
      pipeline's quadratic compression stage dominates and the rank-c
      sketch pays for itself); otherwise ``quantized_gw`` (multiscale),
      which covers fused/unbalanced/indecomposable structure at any
      scale. (For unbalanced problems at this scale the reported value
      is the anchor-level estimate and the refined marginals are
      relaxed — but spar_gw's O((16n)²)-per-iteration assembly is
      infeasible there, so quantized is still the right default.)
    """
    size = max(problem.shape)
    if size <= AUTO_DENSE_MAX:
        return get_solver("dense_gw").default_config(size)
    if size <= AUTO_SPAR_MAX:
        return get_solver("spar_gw").default_config(size)
    # the point-cloud fast route requires the *exact* factorization path
    # (squared-euclidean + l2), which never materializes an n×n matrix;
    # kl point clouds would silently densify for the sketch, so they wait
    # for the _LOWRANK_MIN threshold like precomputed costs
    factorizable = (problem.geom_x.is_point_cloud
                    and problem.geom_y.is_point_cloud
                    and problem.loss == "l2")
    if _lowrank_eligible(problem) and (factorizable
                                       or size > _LOWRANK_MIN):
        return get_solver("lowrank_gw").default_config(size)
    return get_solver("quantized_gw").default_config(size)


@jax.jit
def _solve_jit(problem, solver, key):
    return solver.run(problem, key)


def _solve_failed(out) -> bool:
    """Host-side failure predicate: DIVERGED/STALLED status (any lane) or
    a non-finite value."""
    if out.status is not None and bool(np.any(
            np.asarray(out.status.code) >= STALLED)):
        return True
    return not bool(np.all(np.isfinite(np.asarray(out.value))))


def solve(problem: QuadraticProblem,
          solver: Union[str, object, None] = None,
          key: Optional[jax.Array] = None, validate: bool = True,
          on_failure: str = "none"):
    """Solve a QuadraticProblem; returns a structured ``GWOutput``.

    solver     — a solver config instance; a registry name ("spar_gw",
                 "dense_gw", "grid_gw", "quantized_gw", "lowrank_gw", ...)
                 which selects
                 that solver's ``default_config`` for the problem size; or
                 None to auto-select from the problem structure
                 (:func:`select_solver`)
    key        — PRNG key; required by sampling/multiscale solvers, ignored
                 by dense (checked here, eagerly, so a missing key is a
                 clear ``ValueError`` instead of a mid-trace failure)
    validate   — run the problem's boundary checks if they haven't run yet
                 (construction with validate=True already marks the problem
                 validated; value checks are auto-skipped under tracing;
                 pass False for zero overhead)
    on_failure — what to do when the solve comes back unhealthy (DIVERGED
                 or STALLED status after the solver's own in-jit ε-rescue
                 budget, or a non-finite value):
                 * "none" (default) — return the output as-is; inspect
                   ``out.status`` yourself
                 * "raise" — raise :class:`SolveDivergedError` (the failed
                   output rides on ``.output``)
                 * "fallback" — walk the solver ladder (lowrank →
                   quantized → spar → dense, eligibility-gated; see
                   health/fallback.py), re-keying each attempt with
                   ``jax.random.fold_in(key, attempt)``; returns the first
                   healthy result, or the original failed output if every
                   rung fails.
                 "raise"/"fallback" need concrete outputs, so they are
                 unavailable inside ``jit``/``vmap`` (statuses are traced
                 there — handle failure at the call site instead).
    """
    if on_failure not in ("none", "raise", "fallback"):
        raise ValueError(
            f"on_failure must be 'none', 'raise' or 'fallback', got "
            f"{on_failure!r}")
    if solver is None:
        solver = select_solver(problem)
    elif isinstance(solver, str):
        solver = get_solver(solver).default_config(max(problem.shape))
    if key is None and getattr(type(solver), "requires_key", False):
        raise ValueError(
            f"{type(solver).__name__} needs a PRNG key (it draws a random "
            f"support / anchors / init): call repro.solve(problem, solver, "
            f"key=jax.random.PRNGKey(seed))")
    if validate and not getattr(problem, "_validated", False):
        problem.check()
    out = _solve_jit(problem, solver, key)
    if on_failure == "none":
        return out
    if not (is_concrete(out.value)
            and (out.status is None or is_concrete(out.status.code))):
        raise ValueError(
            "on_failure='raise'/'fallback' inspects concrete solve results "
            "and cannot run under jit/vmap tracing; call solve eagerly or "
            "use on_failure='none' and handle out.status downstream")
    if not _solve_failed(out):
        return out
    primary_name = getattr(type(solver), "name", type(solver).__name__)
    if on_failure == "raise":
        raise SolveDivergedError(
            f"{primary_name} failed: status="
            f"{out.status.describe() if out.status is not None else None}, "
            f"value={np.asarray(out.value)}", output=out)
    # fallback: deterministic ladder walk — attempt k re-keys with
    # fold_in(key, k), so recovered solves are bitwise reproducible
    for attempt, cand in enumerate(
            fallback_chain(problem, exclude=(primary_name,),
                           key_available=key is not None), start=1):
        cand_key = None if key is None else jax.random.fold_in(key, attempt)
        cand_out = _solve_jit(problem, cand, cand_key)
        if not _solve_failed(cand_out):
            return cand_out
    return out
