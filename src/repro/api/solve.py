"""``repro.solve`` — the single front door for every GW variant.

    out = repro.solve(problem, solver=SparGWSolver(s=16 * n), key=key)

``problem`` and ``solver`` are pytrees and the call is jitted internally,
so repeated solves with the same structure (shapes + static knobs) reuse
the compiled executable, and the whole call nests under user ``jax.jit``
and ``jax.vmap`` transforms — batching a stack of problems over keys is

    batched = jax.vmap(lambda p, k: repro.solve(p, solver=s, key=k))
    out = batched(stacked_problems, jax.random.split(key, B))

where ``stacked_problems = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)``.

With ``solver=None`` (the default) a solver is auto-selected from the
problem's structure — see :func:`select_solver`.
"""
from __future__ import annotations

from typing import Optional, Union

import jax

from repro.api.problem import QuadraticProblem
from repro.api.solvers import get_solver

# auto-selection size thresholds (max(m, n)); see select_solver
AUTO_DENSE_MAX = 256
AUTO_SPAR_MAX = 2048
# above this, even the multiscale pipeline's quadratic stages (anchor
# compression, O(m²k) matmuls) dominate — route to the linear-time
# low-rank solver whenever the problem admits it
_LOWRANK_MIN = 8192

# ground losses with a Peyré decomposition L = f1 + f2 - h1·h2 (the
# structure the low-rank gradient factorization needs)
_LOWRANK_LOSSES = ("l2", "kl")


def _lowrank_eligible(problem: QuadraticProblem) -> bool:
    """lowrank_gw handles balanced, non-fused, decomposable-loss problems."""
    return (not problem.is_fused and not problem.is_unbalanced
            and problem.loss in _LOWRANK_LOSSES)


def select_solver(problem: QuadraticProblem):
    """Pick a solver config from the problem's structure (size/variant).

    Heuristic (ROADMAP "solver auto-selection"):

    * max(m, n) ≤ 256 — ``dense_gw``: full-resolution PGA is cheap, exact
      resolution, and needs no PRNG key;
    * ≤ 2048 — ``spar_gw`` with the paper's s = 16n support: the O(s²)
      cost assembly still beats dense O(n³)-per-iteration work;
    * larger — ``lowrank_gw`` when the problem admits it (balanced,
      non-fused, decomposable loss) **and** either both geometries are
      point clouds (exact rank-(d+2) cost factors, zero n×n work) or
      max(m, n) exceeds ``_LOWRANK_MIN`` (where even the multiscale
      pipeline's quadratic compression stage dominates and the rank-c
      sketch pays for itself); otherwise ``quantized_gw`` (multiscale),
      which covers fused/unbalanced/indecomposable structure at any
      scale. (For unbalanced problems at this scale the reported value
      is the anchor-level estimate and the refined marginals are
      relaxed — but spar_gw's O((16n)²)-per-iteration assembly is
      infeasible there, so quantized is still the right default.)
    """
    size = max(problem.shape)
    if size <= AUTO_DENSE_MAX:
        return get_solver("dense_gw").default_config(size)
    if size <= AUTO_SPAR_MAX:
        return get_solver("spar_gw").default_config(size)
    # the point-cloud fast route requires the *exact* factorization path
    # (squared-euclidean + l2), which never materializes an n×n matrix;
    # kl point clouds would silently densify for the sketch, so they wait
    # for the _LOWRANK_MIN threshold like precomputed costs
    factorizable = (problem.geom_x.is_point_cloud
                    and problem.geom_y.is_point_cloud
                    and problem.loss == "l2")
    if _lowrank_eligible(problem) and (factorizable
                                       or size > _LOWRANK_MIN):
        return get_solver("lowrank_gw").default_config(size)
    return get_solver("quantized_gw").default_config(size)


@jax.jit
def _solve_jit(problem, solver, key):
    return solver.run(problem, key)


def solve(problem: QuadraticProblem,
          solver: Union[str, object, None] = None,
          key: Optional[jax.Array] = None, validate: bool = True):
    """Solve a QuadraticProblem; returns a structured ``GWOutput``.

    solver   — a solver config instance; a registry name ("spar_gw",
               "dense_gw", "grid_gw", "quantized_gw", "lowrank_gw", ...)
               which selects
               that solver's ``default_config`` for the problem size; or
               None to auto-select from the problem structure
               (:func:`select_solver`)
    key      — PRNG key; required by sampling/multiscale solvers, ignored
               by dense
    validate — run the problem's boundary checks if they haven't run yet
               (construction with validate=True already marks the problem
               validated; value checks are auto-skipped under tracing;
               pass False for zero overhead)
    """
    if solver is None:
        solver = select_solver(problem)
    elif isinstance(solver, str):
        solver = get_solver(solver).default_config(max(problem.shape))
    if validate and not getattr(problem, "_validated", False):
        problem.check()
    return _solve_jit(problem, solver, key)
