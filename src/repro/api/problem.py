"""``QuadraticProblem`` — the task of coupling two geometries.

One problem class covers the whole family the paper treats as separate
algorithms: plain GW (no extras), fused GW (``M`` or feature geometries +
``fused_penalty``), and unbalanced GW (``lam``). Solvers dispatch on the
problem's *structure* — which optional fields are set — so variant
selection is part of the pytree treedef and stable under ``jit``/``vmap``.
"""
from __future__ import annotations

from dataclasses import InitVar, dataclass
from typing import Any, Optional

import jax.numpy as jnp

from repro.api.geometry import Geometry
from repro.api.pytree import is_concrete, register_pytree_dataclass

_MASS_ATOL = 1e-4


@dataclass(frozen=True)
class QuadraticProblem:
    """A (fused/unbalanced) quadratic OT problem between two geometries.

    geom_x, geom_y — the two spaces (cost + marginal [+ features])
    loss           — ground-loss name ("l2", "l1", "kl"); static
    fused_penalty  — α ∈ (0, 1]: weight of the quadratic term in fused GW,
                     C_fu = α·L⊗T + (1-α)·M. Required iff a linear term is
                     present (explicit ``M`` or features on both geometries)
    M              — optional (m, n) linear cost for fused GW; when absent
                     but both geometries carry features, M is derived as the
                     pairwise squared euclidean feature distance
    lam            — optional λ > 0: unbalanced marginal-KL strength
                     (None → balanced problem, weights must sum to 1)
    validate       — init-only flag; ``False`` skips all checks (callers
                     constructing problems inside traced code). Value checks
                     are auto-skipped for tracer inputs either way.
    """
    geom_x: Geometry
    geom_y: Geometry
    loss: str = "l2"
    fused_penalty: Optional[Any] = None
    M: Optional[Any] = None
    lam: Optional[Any] = None
    validate: InitVar[bool] = True

    def __post_init__(self, validate: bool = True):
        if validate:
            self.check()

    # -- validation ---------------------------------------------------------

    def check(self):
        """Validate shapes always, values only when inputs are concrete.

        Raises ValueError with an actionable message; jit-traced callers
        that want zero overhead pass ``validate=False`` instead. Marks the
        instance as validated so ``solve(validate=True)`` doesn't pay the
        concrete-value device syncs twice per call.
        """
        self.geom_x.check()
        self.geom_y.check()
        m, n = self.shape

        from repro.core import ground_cost as gc
        try:
            gc.get_loss(self.loss)
        except KeyError:
            raise ValueError(
                f"unknown ground loss {self.loss!r} (known: l1, l2, kl)"
            ) from None

        if self.M is not None:
            ms = getattr(self.M, "shape", None)
            if ms != (m, n):
                raise ValueError(
                    f"M must have shape ({m}, {n}) = (len(geom_x), "
                    f"len(geom_y)), got {ms}")
        has_lin = self.M is not None or (
            self.geom_x.features is not None
            and self.geom_y.features is not None)
        if has_lin and self.fused_penalty is None:
            raise ValueError(
                "a linear term (M or features on both geometries) requires "
                "fused_penalty=α to be set (C_fu = α·L⊗T + (1-α)·M)")
        if self.fused_penalty is not None:
            if not has_lin:
                raise ValueError(
                    "fused_penalty set but no linear term: provide M or put "
                    "features on both geometries")
            if is_concrete(self.fused_penalty):
                alpha = float(self.fused_penalty)
                if not 0.0 < alpha <= 1.0:
                    raise ValueError(
                        f"fused_penalty must lie in (0, 1], got {alpha}")
        if (self.geom_x.features is not None) != (
                self.geom_y.features is not None) and self.M is None:
            raise ValueError(
                "features must be set on both geometries (or neither) "
                "when no explicit M is given")
        if self.lam is not None and is_concrete(self.lam):
            if float(self.lam) <= 0.0:
                raise ValueError(f"lam must be > 0, got {float(self.lam)}")
        if self.lam is None:
            # balanced problem: marginals must be probability vectors
            for name, w in (("geom_x", self.geom_x.weights),
                            ("geom_y", self.geom_y.weights)):
                if is_concrete(w):
                    total = float(jnp.sum(w))
                    if abs(total - 1.0) > _MASS_ATOL:
                        raise ValueError(
                            f"{name}.weights must sum to 1 for a balanced "
                            f"problem (got {total:.6f}); normalize them or "
                            f"pass lam=... for an unbalanced problem")
        object.__setattr__(self, "_validated", True)
        return self

    # -- structure ----------------------------------------------------------

    @property
    def shape(self):
        return (self.geom_x.n, self.geom_y.n)

    @property
    def is_fused(self) -> bool:
        return self.M is not None or (
            self.geom_x.features is not None
            and self.geom_y.features is not None)

    @property
    def is_unbalanced(self) -> bool:
        return self.lam is not None

    # -- fused linear term --------------------------------------------------

    def linear_cost_dense(self):
        """The (m, n) linear cost M (explicit, or derived from features)."""
        if self.M is not None:
            return self.M
        fx, fy = self.geom_x.features, self.geom_y.features
        return jnp.sum((fx[:, None, :] - fy[None, :, :]) ** 2, axis=-1)

    def linear_cost_at(self, rows, cols):
        """M gathered on a COO support — O(s·d), never materializes (m, n)."""
        if self.M is not None:
            return self.M[rows, cols]
        fx, fy = self.geom_x.features, self.geom_y.features
        return jnp.sum((fx[rows] - fy[cols]) ** 2, axis=-1)


register_pytree_dataclass(
    QuadraticProblem,
    data_fields=("geom_x", "geom_y", "fused_penalty", "M", "lam"),
    meta_fields=("loss",))
