"""Solver configurations + runners for the unified Problem/Solver/Output API.

Each solver is a frozen dataclass registered twice:

* as a **pytree** — ``epsilon`` is a dynamic leaf (regularization sweeps
  don't retrace), everything that selects code paths or loop bounds
  (iteration budgets, tolerances, impl switches, support sizes) is static
  metadata;
* in a **name registry** (``get_solver`` / ``available_solvers``) so CLIs
  and configs can select any solver by string and new solvers plug in via
  ``@register_solver("name")`` without touching call sites.

``run(problem, key)`` dispatches on the *structure* of the problem:
``lam`` set → unbalanced variant, linear term present → fused variant.
All outer loops go through the shared tolerance-aware driver
(api/driver.py) and all inner Sinkhorn projections accept ``inner_tol``,
so every variant reports per-iteration marginal errors and supports early
stopping uniformly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.api.driver import pga_loop
from repro.api.output import GridCoupling, GWOutput, SparseCoupling
from repro.api.pytree import register_pytree_dataclass
from repro.core import sampling
from repro.core.grid_gw import _dedup_marginal, grid_cost
from repro.core.gw import dense_cost, gw_objective
from repro.core.sinkhorn import (
    sinkhorn,
    sinkhorn_log,
    sinkhorn_unbalanced_log,
    sparse_sinkhorn,
    sparse_sinkhorn_logdomain,
    sparse_sinkhorn_unbalanced_log,
)
from repro.core.spar_ugw import _marginal_penalty
from repro.core.utils import quadratic_kl
from repro.kernels.spar_cost.ops import make_spar_cost_fn

# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register_solver(name: str):
    """Class decorator: register a solver config under a CLI-friendly name.

    ``repro.solve`` passes solver configs through ``jax.jit`` as pytree
    arguments, so a solver class must also be a registered pytree. Classes
    that didn't call ``register_pytree_dataclass`` themselves (e.g.
    third-party subclasses of the built-in solvers) are auto-registered
    here with ``epsilon`` as the single dynamic leaf and every other
    dataclass field as static metadata.
    """
    def deco(cls):
        if name in _REGISTRY:
            raise ValueError(f"solver name {name!r} already registered")
        # a hollow instance showing up as a pytree *leaf* means cls (as an
        # exact type — registration doesn't inherit) is not registered yet
        if jax.tree_util.all_leaves([object.__new__(cls)]):
            fields = tuple(f.name for f in dataclasses.fields(cls))
            data = tuple(f for f in fields if f in ("epsilon", "fault"))
            meta = tuple(f for f in fields if f not in data)
            register_pytree_dataclass(cls, data, meta)
        _REGISTRY[name] = cls
        cls.name = name
        return cls
    return deco


def get_solver(name: str):
    """Look up a solver class by registry name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown solver {name!r}; available: "
            f"{', '.join(available_solvers())}") from None


def available_solvers():
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def _coo_marginal_err(T, rows, cols, a, b):
    mu = jax.ops.segment_sum(T, rows, num_segments=a.shape[0])
    nu = jax.ops.segment_sum(T, cols, num_segments=b.shape[0])
    return jnp.sum(jnp.abs(mu - a)) + jnp.sum(jnp.abs(nu - b))


def _dense_marginal_err(T, a, b):
    return (jnp.sum(jnp.abs(T.sum(axis=1) - a))
            + jnp.sum(jnp.abs(T.sum(axis=0) - b)))


def _spar_pga_step(T, scale, cost_fn, a, b, rows, cols, w, logw, m: int,
                   n: int, epsilon, inner_iters: int, inner_tol: float,
                   reg: str, stable: bool, alpha=1.0, lin=0.0):
    """One proximal/entropic PGA outer step on the COO support.

    Shared by SPAR-GW (α = 1, lin = 0) and SPAR-FGW (lin = M̃): the
    iteration cost is C = α·(L @ T̃) + (1-α)·lin, and in the stable path
    the fused cost_fn writes logK = -C/ε + log w (+ log T̃) directly.
    ``scale`` is the driver's ε-rescue escalation (1.0 until a rescue
    fires; each rescue doubles it, flattening the kernel).
    """
    epsilon = epsilon * scale
    if stable:
        off = logw - ((1.0 - alpha) / epsilon) * lin
        if reg == "prox":
            off = off + jnp.log(jnp.maximum(T, 1e-38))
        logK = cost_fn((-alpha / epsilon) * T, off)
        return sparse_sinkhorn_logdomain(a, b, rows, cols, logK, m, n,
                                         inner_iters, tol=inner_tol)
    C = cost_fn(alpha * T, (1.0 - alpha) * lin)
    Cs = C - jnp.min(C)          # constant shift — Sinkhorn-invariant
    K = jnp.exp(-Cs / epsilon) * w
    if reg == "prox":
        K = K * T
    return sparse_sinkhorn(a, b, rows, cols, K, m, n, inner_iters,
                           tol=inner_tol)


def _require_key(key, solver_name: str):
    if key is None:
        raise ValueError(
            f"{solver_name} draws a random support: call "
            f"repro.solve(problem, solver, key=jax.random.PRNGKey(...))")


def _health_kw(solver):
    """Driver keywords wiring a config's rescue/fault knobs into pga_loop."""
    return dict(scaled_step=True, max_rescues=solver.max_rescues,
                rescue_factor=solver.rescue_factor, fault=solver.fault,
                trace=solver.trace)


# ---------------------------------------------------------------------------
# SPAR-GW (Algorithms 2, 3, 4 — COO importance sparsification)
# ---------------------------------------------------------------------------

@register_solver("spar_gw")
@dataclass(frozen=True)
class SparGWSolver:
    """Importance-sparsified GW — the paper's contribution.

    Covers Alg. 2 (GW), Alg. 4 (fused, problem carries a linear term) and
    Alg. 3 (unbalanced, problem carries ``lam``). ``s`` is the sampled
    support size (the paper uses s = 16n); ``cost_impl`` selects the
    O(s²) cost-assembly backend (kernels/spar_cost). ``max_rescues`` /
    ``rescue_factor`` bound the driver's in-jit ε-rescue restarts on
    detected divergence (ε-doubling from the last healthy iterate);
    ``fault`` is the chaos-testing hook (health/faults.py); ``trace``
    records per-iteration convergence buffers onto ``output.trace``
    (obs/trace.py — off by default, zero cost and zero leaves when off).
    """
    s: int = 0
    reg: str = "prox"
    epsilon: Any = 1e-2
    outer_iters: int = 20
    inner_iters: int = 50
    tol: float = 0.0
    inner_tol: float = 0.0
    shrink: float = 0.0
    cost_chunk: int = 1024
    stable: bool = True
    cost_impl: str = "auto"
    max_rescues: int = 2
    rescue_factor: float = 2.0
    fault: Any = None
    trace: bool = False

    requires_key = True

    @classmethod
    def default_config(cls, n: int):
        return cls(s=16 * n)

    def run(self, problem, key=None) -> GWOutput:
        if self.s <= 0:
            raise ValueError(
                "SparGWSolver.s (sampled support size) must be > 0; the "
                "paper's default is SparGWSolver(s=16 * n), or use "
                "SparGWSolver.default_config(n)")
        _require_key(key, "SparGWSolver")
        if problem.is_unbalanced:
            if problem.is_fused:
                raise NotImplementedError(
                    "fused + unbalanced GW is not implemented")
            return self._run_unbalanced(problem, key)
        return self._run_balanced(problem, key)

    def _run_balanced(self, problem, key) -> GWOutput:
        Cx, a = problem.geom_x.cost_matrix, problem.geom_x.weights
        Cy, b = problem.geom_y.cost_matrix, problem.geom_y.weights
        m, n = a.shape[0], b.shape[0]
        probs = sampling.balanced_probs(a, b, self.shrink)
        rows, cols = sampling.sample_pairs(key, probs, self.s)
        p = probs.pair_prob(rows, cols)                     # (s,)
        w = 1.0 / (self.s * p)                              # importance adj.
        T0 = a[rows] * b[cols]                              # step 4 init on S
        cost_fn = make_spar_cost_fn(Cx, Cy, rows, cols, problem.loss,
                                    impl=self.cost_impl, chunk=self.cost_chunk)
        fused = problem.is_fused
        alpha = problem.fused_penalty if fused else 1.0
        lin = problem.linear_cost_at(rows, cols) if fused else 0.0
        step = partial(_spar_pga_step, cost_fn=cost_fn, a=a, b=b, rows=rows,
                       cols=cols, w=w, logw=jnp.log(w), m=m, n=n,
                       epsilon=self.epsilon, inner_iters=self.inner_iters,
                       inner_tol=self.inner_tol, reg=self.reg,
                       stable=self.stable, alpha=alpha, lin=lin)
        err_fn = partial(_coo_marginal_err, rows=rows, cols=cols, a=a, b=b)

        def obj_fn(t):          # the step-8 plug-in objective, per iteration
            quad_t = jnp.sum(t * cost_fn(t))
            if fused:
                return alpha * quad_t + (1.0 - alpha) * jnp.sum(lin * t)
            return quad_t

        T, errors, n_iters, converged, status, trace = pga_loop(
            step, err_fn, T0, self.outer_iters, self.tol,
            obj_fn=obj_fn, **_health_kw(self))
        # Step 8: plug-in objective on the sparse support, O(s²).
        quad = jnp.sum(T * cost_fn(T))
        if fused:
            value = alpha * quad + (1.0 - alpha) * jnp.sum(lin * T)
        else:
            value = quad
        return GWOutput(value=value, coupling=SparseCoupling(rows, cols, T),
                        errors=errors, converged=converged, n_iters=n_iters,
                        status=status, trace=trace)

    def _run_unbalanced(self, problem, key) -> GWOutput:
        Cx, a = problem.geom_x.cost_matrix, problem.geom_x.weights
        Cy, b = problem.geom_y.cost_matrix, problem.geom_y.weights
        lam, loss, eps = problem.lam, problem.loss, self.epsilon
        m, n = a.shape[0], b.shape[0]
        scale = jnp.sqrt(jnp.sum(a) * jnp.sum(b))

        # steps 2-3: dense rank-one init and its (log-)kernel — computed once
        Td = a[:, None] * b[None, :] / scale
        m0 = jnp.sum(Td)
        C0 = dense_cost(Cx, Cy, Td, loss) + _marginal_penalty(
            Td.sum(1), Td.sum(0), a, b, lam)
        logK0 = -C0 / (eps * m0) + jnp.log(jnp.maximum(Td, 1e-38))

        # steps 4-5: sampling probability (eq. 9) and index set
        P = sampling.unbalanced_probs(a, b, logK0, lam, eps, self.shrink)
        rows, cols = sampling.sample_pairs_2d(key, P, self.s)
        p = P[rows, cols]
        logw = -jnp.log(self.s * jnp.maximum(p, 1e-38))
        T0 = a[rows] * b[cols] / scale
        cost_fn = make_spar_cost_fn(Cx, Cy, rows, cols, loss,
                                    impl=self.cost_impl, chunk=self.cost_chunk)

        def step(T, scale):
            mT = jnp.sum(T)
            eps_bar = eps * scale * mT      # scale: driver ε-rescue escalation
            lam_bar = lam * mT
            mu = jax.ops.segment_sum(T, rows, num_segments=m)
            nu = jax.ops.segment_sum(T, cols, num_segments=n)
            # fused: logK = -(L@T̃ + penalty)/ε̄ + log T̃ + log w in one pass
            off = (-_marginal_penalty(mu, nu, a, b, lam) / eps_bar
                   + jnp.log(jnp.maximum(T, 1e-38)) + logw)
            logK = cost_fn((-1.0 / eps_bar) * T, off)
            T_new = sparse_sinkhorn_unbalanced_log(
                a, b, rows, cols, logK, lam_bar, eps_bar, m, n,
                self.inner_iters, tol=self.inner_tol)
            # step 10: mass rescaling
            return jnp.sqrt(mT / jnp.maximum(jnp.sum(T_new), 1e-30)) * T_new

        err_fn = partial(_coo_marginal_err, rows=rows, cols=cols, a=a, b=b)

        def obj_fn(t):          # Alg. 3 step-11 UGW objective, per iteration
            mu_t = jax.ops.segment_sum(t, rows, num_segments=m)
            nu_t = jax.ops.segment_sum(t, cols, num_segments=n)
            return (jnp.sum(t * cost_fn(t))
                    + lam * quadratic_kl(mu_t, a)
                    + lam * quadratic_kl(nu_t, b))

        T, errors, n_iters, converged, status, trace = pga_loop(
            step, err_fn, T0, self.outer_iters, self.tol,
            obj_fn=obj_fn, **_health_kw(self))
        # Alg. 3 step 11: UGW objective on the sparse coupling
        mu = jax.ops.segment_sum(T, rows, num_segments=m)
        nu = jax.ops.segment_sum(T, cols, num_segments=n)
        value = (jnp.sum(T * cost_fn(T))
                 + lam * quadratic_kl(mu, a) + lam * quadratic_kl(nu, b))
        return GWOutput(value=value, coupling=SparseCoupling(rows, cols, T),
                        errors=errors, converged=converged, n_iters=n_iters,
                        status=status, trace=trace)


# ---------------------------------------------------------------------------
# Dense GW (Algorithm 1 baselines: EGW / PGA-GW / fused / unbalanced)
# ---------------------------------------------------------------------------

@register_solver("dense_gw")
@dataclass(frozen=True)
class DenseGWSolver:
    """Dense EGW (reg='ent') / PGA-GW (reg='prox') — the paper's benchmark.

    Handles fused (problem linear term) and unbalanced (problem ``lam``)
    variants; the unbalanced path always runs in log domain.
    """
    reg: str = "prox"
    epsilon: Any = 1e-2
    outer_iters: int = 20
    inner_iters: int = 50
    tol: float = 0.0
    inner_tol: float = 0.0
    stable: bool = True
    max_rescues: int = 2
    rescue_factor: float = 2.0
    fault: Any = None
    trace: bool = False

    requires_key = False

    @classmethod
    def default_config(cls, n: int):
        return cls()

    def run(self, problem, key=None) -> GWOutput:
        # key accepted for interface uniformity; the solver is deterministic
        if problem.is_unbalanced:
            if problem.is_fused:
                raise NotImplementedError(
                    "fused + unbalanced GW is not implemented")
            return self._run_unbalanced(problem)
        return self._run_balanced(problem)

    def _run_balanced(self, problem) -> GWOutput:
        Cx, a = problem.geom_x.cost_matrix, problem.geom_x.weights
        Cy, b = problem.geom_y.cost_matrix, problem.geom_y.weights
        loss = problem.loss
        fused = problem.is_fused
        alpha = problem.fused_penalty if fused else 1.0
        M = problem.linear_cost_dense() if fused else None
        T0 = a[:, None] * b[None, :]

        def step(T, scale):
            eps = self.epsilon * scale      # scale: driver ε-rescue escalation
            C = dense_cost(Cx, Cy, T, loss)
            if fused:
                C = alpha * C + (1 - alpha) * M
            if self.stable:
                logK = -C / eps
                if self.reg == "prox":
                    logK = logK + jnp.log(jnp.maximum(T, 1e-38))
                return sinkhorn_log(a, b, logK, self.inner_iters,
                                    tol=self.inner_tol)
            Cs = C - jnp.min(C)      # constant shift — Sinkhorn-invariant
            K = jnp.exp(-Cs / eps)
            if self.reg == "prox":
                K = K * T
            return sinkhorn(a, b, K, self.inner_iters, tol=self.inner_tol)

        err_fn = partial(_dense_marginal_err, a=a, b=b)

        def obj_fn(t):
            quad_t = gw_objective(Cx, Cy, t, loss)
            if fused:
                return alpha * quad_t + (1 - alpha) * jnp.sum(M * t)
            return quad_t

        T, errors, n_iters, converged, status, trace = pga_loop(
            step, err_fn, T0, self.outer_iters, self.tol,
            obj_fn=obj_fn, **_health_kw(self))
        quad = gw_objective(Cx, Cy, T, loss)
        if fused:
            value = alpha * quad + (1 - alpha) * jnp.sum(M * T)
        else:
            value = quad
        return GWOutput(value=value, coupling=T, errors=errors,
                        converged=converged, n_iters=n_iters, status=status,
                        trace=trace)

    def _run_unbalanced(self, problem) -> GWOutput:
        Cx, a = problem.geom_x.cost_matrix, problem.geom_x.weights
        Cy, b = problem.geom_y.cost_matrix, problem.geom_y.weights
        lam, loss, eps = problem.lam, problem.loss, self.epsilon
        T0 = a[:, None] * b[None, :] / jnp.sqrt(jnp.sum(a) * jnp.sum(b))

        def step(T, scale):
            mT = jnp.sum(T)
            eps_bar = eps * scale * mT      # scale: driver ε-rescue escalation
            lam_bar = lam * mT
            C = dense_cost(Cx, Cy, T, loss) + _marginal_penalty(
                T.sum(1), T.sum(0), a, b, lam)
            logK = -C / eps_bar + jnp.log(jnp.maximum(T, 1e-38))
            T_new = sinkhorn_unbalanced_log(a, b, logK, lam_bar, eps_bar,
                                            self.inner_iters,
                                            tol=self.inner_tol)
            return jnp.sqrt(mT / jnp.maximum(jnp.sum(T_new), 1e-30)) * T_new

        err_fn = partial(_dense_marginal_err, a=a, b=b)

        def obj_fn(t):
            return (jnp.sum(t * dense_cost(Cx, Cy, t, loss))
                    + lam * quadratic_kl(t.sum(1), a)
                    + lam * quadratic_kl(t.sum(0), b))

        T, errors, n_iters, converged, status, trace = pga_loop(
            step, err_fn, T0, self.outer_iters, self.tol,
            obj_fn=obj_fn, **_health_kw(self))
        value = (jnp.sum(T * dense_cost(Cx, Cy, T, loss))
                 + lam * quadratic_kl(T.sum(1), a)
                 + lam * quadratic_kl(T.sum(0), b))
        return GWOutput(value=value, coupling=T, errors=errors,
                        converged=converged, n_iters=n_iters, status=status,
                        trace=trace)


# ---------------------------------------------------------------------------
# Grid-SPAR-GW (beyond-paper TPU-native factorized sparsification)
# ---------------------------------------------------------------------------

@register_solver("grid_gw")
@dataclass(frozen=True)
class GridGWSolver:
    """Grid-structured SPAR-GW: support = R × C, dense s_r × s_c block.

    Balanced problems only (no fused/unbalanced grid variant yet).
    ``use_kernel`` routes the arbitrary-loss cost assembly through the
    Pallas gw_cost kernel.
    """
    s_r: int = 0
    s_c: int = 0
    reg: str = "prox"
    epsilon: Any = 1e-2
    outer_iters: int = 20
    inner_iters: int = 50
    tol: float = 0.0
    inner_tol: float = 0.0
    shrink: float = 0.0
    use_kernel: bool = False
    stable: bool = True
    max_rescues: int = 2
    rescue_factor: float = 2.0
    fault: Any = None
    trace: bool = False

    requires_key = True

    @classmethod
    def default_config(cls, n: int):
        side = max(8, int(round((16 * n) ** 0.5)))   # equal budget s = 16n
        return cls(s_r=side, s_c=side)

    def run(self, problem, key=None) -> GWOutput:
        if self.s_r <= 0 or self.s_c <= 0:
            raise ValueError(
                "GridGWSolver requires s_r > 0 and s_c > 0 (grid support "
                "side lengths); use GridGWSolver.default_config(n)")
        _require_key(key, "GridGWSolver")
        if problem.is_fused or problem.is_unbalanced:
            raise NotImplementedError(
                "GridGWSolver supports balanced non-fused problems only; "
                "use SparGWSolver for fused/unbalanced variants")
        Cx, a = problem.geom_x.cost_matrix, problem.geom_x.weights
        Cy, b = problem.geom_y.cost_matrix, problem.geom_y.weights
        loss = problem.loss
        m, n = a.shape[0], b.shape[0]
        probs = sampling.balanced_probs(a, b, self.shrink)
        R, C = sampling.sample_grid(key, probs, self.s_r, self.s_c)
        CxR = Cx[R][:, R]                                # (s_r, s_r) — once
        CyC = Cy[C][:, C]                                # (s_c, s_c) — once
        s = self.s_r * self.s_c
        w = 1.0 / (s * probs.pa[R][:, None] * probs.pb[C][None, :])
        aR = _dedup_marginal(R, a, m)
        bC = _dedup_marginal(C, b, n)
        # normalize to unit mass (covered-support renorm.; DESIGN.md §4)
        aR = aR / aR.sum()
        bC = bC / bC.sum()
        T0 = aR[:, None] * bC[None, :]

        def step(T, scale):
            eps = self.epsilon * scale      # scale: driver ε-rescue escalation
            Cmat = grid_cost(CxR, CyC, T, loss, self.use_kernel)
            if self.stable:
                logK = -Cmat / eps + jnp.log(w)
                if self.reg == "prox":
                    logK = logK + jnp.log(jnp.maximum(T, 1e-38))
                return sinkhorn_log(aR, bC, logK, self.inner_iters,
                                    tol=self.inner_tol)
            Cs = Cmat - jnp.min(Cmat)
            K = jnp.exp(-Cs / eps) * w
            if self.reg == "prox":
                K = K * T
            return sinkhorn(aR, bC, K, self.inner_iters, tol=self.inner_tol)

        err_fn = partial(_dense_marginal_err, a=aR, b=bC)

        def obj_fn(t):
            return jnp.sum(t * grid_cost(CxR, CyC, t, loss, self.use_kernel))

        T, errors, n_iters, converged, status, trace = pga_loop(
            step, err_fn, T0, self.outer_iters, self.tol,
            obj_fn=obj_fn, **_health_kw(self))
        value = jnp.sum(T * grid_cost(CxR, CyC, T, loss, self.use_kernel))
        return GWOutput(value=value, coupling=GridCoupling(R, C, T),
                        errors=errors, converged=converged, n_iters=n_iters,
                        status=status, trace=trace)


