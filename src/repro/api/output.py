"""Structured solver output — ``GWOutput`` + coupling containers.

Every solver returns the same shape of result regardless of variant, so
downstream code (benchmarks, batching, serving) never unpacks per-solver
tuples. All containers are pytrees: a ``vmap``-batched solve returns one
``GWOutput`` whose leaves carry the batch dimension.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax.numpy as jnp

from repro.api.pytree import register_pytree_dataclass
from repro.health.status import SolveStatus


class SparseCoupling(NamedTuple):
    """COO coupling on a sampled support of size s.

    Duplicate (row, col) pairs are legitimate parallel entries of the
    importance-sampling estimator; ``todense`` merges them by summation
    (matching the segment-sum Sinkhorn semantics).
    """
    rows: Any   # (s,) int
    cols: Any   # (s,) int
    vals: Any   # (s,) float

    def todense(self, m: int, n: int):
        Z = jnp.zeros((m, n), self.vals.dtype)
        return Z.at[self.rows, self.cols].add(self.vals)


class GridCoupling(NamedTuple):
    """Factorized (grid) coupling: block[k, l] sits at (rows[k], cols[l])."""
    rows: Any    # (s_r,) int
    cols: Any    # (s_c,) int
    block: Any   # (s_r, s_c) float

    def todense(self, m: int, n: int):
        Z = jnp.zeros((m, n), self.block.dtype)
        return Z.at[self.rows[:, None], self.cols[None, :]].add(self.block)


class QuantizedCoupling(NamedTuple):
    """Hierarchical coupling from the multiscale pipeline (DESIGN.md §6).

    One refined member×member block per supported anchor pair of the
    coarse coupling. Padded member slots carry point index 0 with block
    value exactly 0.0, so flattening/scattering needs no separate mask and
    ``tocoo()`` is COO-compatible with the SparseCoupling consumers
    (duplicate (0, 0) padding entries merge to +0 by summation).
    """
    pair_rows: Any   # (B,) int — anchor id on the X side of each block
    pair_cols: Any   # (B,) int — anchor id on the Y side of each block
    members_x: Any   # (B, cap_x) int — fine point indices (0 where padded)
    members_y: Any   # (B, cap_y) int
    blocks: Any      # (B, cap_x, cap_y) float — 0.0 on padded slots

    def tocoo(self):
        """Flatten to COO (rows, cols, vals) of length B·cap_x·cap_y."""
        Bn, cx, cy = self.blocks.shape
        rows = jnp.broadcast_to(self.members_x[:, :, None], (Bn, cx, cy))
        cols = jnp.broadcast_to(self.members_y[:, None, :], (Bn, cx, cy))
        return rows.reshape(-1), cols.reshape(-1), self.blocks.reshape(-1)

    def todense(self, m: int, n: int):
        rows, cols, vals = self.tocoo()
        return jnp.zeros((m, n), self.blocks.dtype).at[rows, cols].add(vals)

    def marginals(self, m: int, n: int):
        """(mu, nu) of the refined coupling — O(B·cap²), never densifies."""
        mu = jnp.zeros((m,), self.blocks.dtype).at[
            self.members_x.reshape(-1)].add(self.blocks.sum(axis=2).reshape(-1))
        nu = jnp.zeros((n,), self.blocks.dtype).at[
            self.members_y.reshape(-1)].add(self.blocks.sum(axis=1).reshape(-1))
        return mu, nu


class LowRankCoupling(NamedTuple):
    """Factored coupling T = Q diag(1/g) Rᵀ (Scetbon et al., 2021/22).

    Storage is O((m + n)·r): ``q`` ∈ ℝ^{m×r} with row sums ≈ a, ``r`` ∈
    ℝ^{n×r} with row sums ≈ b, and both column sums ≈ ``g`` ∈ Δ_r. Unlike
    the COO containers there is no sparsity pattern — the coupling is
    dense but *never materialized* by the solver; ``todense``/``tocoo``
    exist for small-problem interop with the COO consumers.
    """
    q: Any   # (m, r) float — left factor, Q 1_r ≈ a
    r: Any   # (n, r) float — right factor, R 1_r ≈ b
    g: Any   # (r,)  float — shared inner marginal (≥ the solver's floor)

    @property
    def rank(self) -> int:
        return self.g.shape[-1]

    def apply(self, x, axis: int = 0):
        """``T @ x`` (axis=0) or ``Tᵀ @ x`` (axis=1) in O((m + n)·r) —
        the matvec contract that keeps every downstream use linear.
        ``x`` may be a vector or a (⋅, k) stack of vectors."""
        left, right = (self.q, self.r) if axis == 0 else (self.r, self.q)
        y = right.T @ x                                    # (r,) or (r, k)
        y = y / (self.g[:, None] if y.ndim > 1 else self.g)
        return left @ y

    def marginals(self, m: int = None, n: int = None):
        """(mu, nu) of the coupling T = Q diag(1/g) Rᵀ — O((m + n)·r).

        Computed from T itself (T 1 = Q diag(1/g) (Rᵀ1)), not as the
        factor row sums: the two differ by whatever inner-marginal
        violation (Qᵀ1, Rᵀ1 vs g) the Dykstra budget left behind, and
        this container's contract — like every other coupling's — is to
        report the marginals of the coupling it stores.
        """
        mu = self.q @ ((self.r.sum(axis=0)) / self.g)
        nu = self.r @ ((self.q.sum(axis=0)) / self.g)
        return mu, nu

    def todense(self, m: int = None, n: int = None):
        """Materialize the (m, n) coupling (small-problem interop only;
        the shape is implied by the factors, args accepted for interface
        parity with the other containers)."""
        return (self.q / self.g[None, :]) @ self.r.T

    def tocoo(self):
        """Flatten to COO (rows, cols, vals) of length m·n — the coupling
        is dense, so this is only for small-problem COO interop."""
        T = self.todense()
        m, n = T.shape
        rows = jnp.repeat(jnp.arange(m), n)
        cols = jnp.tile(jnp.arange(n), m)
        return rows, cols, T.reshape(-1)


@dataclass(frozen=True)
class GWOutput:
    """Result of one GW solve.

    value     — scalar objective estimate (GW/FGW/UGW value)
    coupling  — (m, n) dense array, ``SparseCoupling``, ``GridCoupling``,
                ``QuantizedCoupling``, or ``LowRankCoupling``
    errors    — (outer_iters,) marginal-violation ℓ1 error recorded after
                each outer iteration; NaN beyond ``n_iters``
    converged — True iff the outer loop hit the tolerance before the bound
                (always False when the solver ran with ``tol=0``)
    n_iters   — number of outer iterations actually taken
    status    — per-lane :class:`~repro.health.status.SolveStatus`
                (CONVERGED / MAXITER / STALLED / DIVERGED, iteration of
                first failure, last finite error, rescues consumed);
                ``None`` only for outputs built by pre-health code
    trace     — per-iteration :class:`~repro.obs.trace.ConvergenceTrace`
                buffers (err / objective / delta / mass / rescue scale /
                rescue events) when the solver ran with ``trace=True``;
                ``None`` otherwise — tracing off adds zero pytree leaves
    """
    value: Any
    coupling: Any
    errors: Any
    converged: Any
    n_iters: Any
    status: Optional[SolveStatus] = None
    trace: Optional[Any] = None

    def coupling_dense(self, m: int, n: int):
        """The coupling as a dense (m, n) matrix, whatever its storage."""
        if hasattr(self.coupling, "todense"):
            return self.coupling.todense(m, n)
        return self.coupling


register_pytree_dataclass(
    GWOutput,
    data_fields=("value", "coupling", "errors", "converged", "n_iters",
                 "status", "trace"))
