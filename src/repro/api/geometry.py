"""``Geometry`` — one side of a (fused) GW problem.

OTT-style separation: a Geometry owns the *space* (pairwise ground cost,
marginal weights, optional node features); the QuadraticProblem owns the
*coupling task* between two geometries; solvers own the *algorithm*.
"""
from __future__ import annotations

from dataclasses import InitVar, dataclass
from typing import Any, Optional

from repro.api.pytree import is_concrete, register_pytree_dataclass


def _shape(x):
    return getattr(x, "shape", None)


@dataclass(frozen=True)
class Geometry:
    """Cost matrix + marginal (+ optional features) for one space.

    cost     — (n, n) pairwise ground cost/similarity matrix
    weights  — (n,) marginal weights (must sum to 1 in balanced problems;
               checked at the QuadraticProblem boundary)
    features — optional (n, d) node features; when both geometries carry
               features and the problem has no explicit ``M``, the fused
               linear term is the pairwise squared euclidean feature cost
    validate — init-only flag; ``False`` skips all checks (for callers
               building geometries inside ``jit``-traced code). Value
               checks are auto-skipped for tracer inputs either way.
    """
    cost: Any
    weights: Any
    features: Optional[Any] = None
    validate: InitVar[bool] = True

    def __post_init__(self, validate: bool = True):
        if validate:
            self.check()

    def check(self):
        """Shape checks (tracer-safe) + value checks (concrete inputs only)."""
        c, w = self.cost, self.weights
        cs, ws = _shape(c), _shape(w)
        if cs is None or len(cs) != 2 or cs[0] != cs[1]:
            raise ValueError(
                f"Geometry.cost must be a square (n, n) matrix, got shape {cs}")
        if ws is None or len(ws) != 1 or ws[0] != cs[0]:
            raise ValueError(
                f"Geometry.weights must have shape ({cs[0]},) to match cost, "
                f"got shape {ws}")
        if self.features is not None:
            fs = _shape(self.features)
            if fs is None or len(fs) != 2 or fs[0] != cs[0]:
                raise ValueError(
                    f"Geometry.features must have shape ({cs[0]}, d) to match "
                    f"cost, got shape {fs}")
        if is_concrete(w):
            import numpy as np
            if float(np.min(np.asarray(w))) < 0.0:
                raise ValueError("Geometry.weights must be non-negative")

    @property
    def n(self) -> int:
        return self.cost.shape[0]


register_pytree_dataclass(Geometry, ("cost", "weights", "features"))
