"""``Geometry`` — one side of a (fused) GW problem.

OTT-style separation: a Geometry owns the *space* (pairwise ground cost,
marginal weights, optional node features); the QuadraticProblem owns the
*coupling task* between two geometries; solvers own the *algorithm*.

A Geometry is backed either by an explicit ``(n, n)`` cost matrix or by a
``points`` array (an ``(n, d)`` point cloud whose implied cost is the
squared euclidean distance matrix). Point-cloud geometries are what the
low-rank solver family exploits: ``||x_i - x_j||²`` factors *exactly* at
rank d+2, so the solver never materializes the n×n cost. Solvers that do
need the dense matrix read ``cost_matrix``, which returns the explicit
cost or assembles it from the points on demand.
"""
from __future__ import annotations

from dataclasses import InitVar, dataclass
from typing import Any, Optional

from repro.api.pytree import is_concrete, register_pytree_dataclass


def _shape(x):
    return getattr(x, "shape", None)


@dataclass(frozen=True)
class Geometry:
    """Cost matrix (or point cloud) + marginal (+ optional features).

    cost     — (n, n) pairwise ground cost/similarity matrix; may be None
               when ``points`` is given (the implied cost is then the
               squared euclidean distance matrix of the points)
    weights  — (n,) marginal weights (must sum to 1 in balanced problems;
               checked at the QuadraticProblem boundary)
    features — optional (n, d) node features; when both geometries carry
               features and the problem has no explicit ``M``, the fused
               linear term is the pairwise squared euclidean feature cost
    points   — optional (n, d) point cloud. With ``cost=None`` it *defines*
               the geometry (squared euclidean cost); alongside an explicit
               cost it is advisory (solvers may ignore it). Point-cloud
               geometries unlock the exact rank-(d+2) cost factorization
               used by ``lowrank_gw``.
    validate — init-only flag; ``False`` skips all checks (for callers
               building geometries inside ``jit``-traced code). Value
               checks are auto-skipped for tracer inputs either way.
    """
    cost: Optional[Any]
    weights: Any
    features: Optional[Any] = None
    points: Optional[Any] = None
    validate: InitVar[bool] = True

    def __post_init__(self, validate: bool = True):
        if validate:
            self.check()

    def check(self):
        """Shape checks (tracer-safe) + value checks (concrete inputs only)."""
        c, w = self.cost, self.weights
        cs, ws = _shape(c), _shape(w)
        if c is None:
            ps = _shape(self.points)
            if ps is None or len(ps) != 2:
                raise ValueError(
                    "Geometry needs an (n, n) cost matrix or an (n, d) "
                    f"points array; got cost=None, points shape {ps}")
            n = ps[0]
        else:
            if cs is None or len(cs) != 2 or cs[0] != cs[1]:
                raise ValueError(
                    f"Geometry.cost must be a square (n, n) matrix, got "
                    f"shape {cs}")
            n = cs[0]
            if self.points is not None:
                ps = _shape(self.points)
                if ps is None or len(ps) != 2 or ps[0] != n:
                    raise ValueError(
                        f"Geometry.points must have shape ({n}, d) to match "
                        f"cost, got shape {ps}")
        if ws is None or len(ws) != 1 or ws[0] != n:
            raise ValueError(
                f"Geometry.weights must have shape ({n},) to match the "
                f"geometry size, got shape {ws}")
        if self.features is not None:
            fs = _shape(self.features)
            if fs is None or len(fs) != 2 or fs[0] != n:
                raise ValueError(
                    f"Geometry.features must have shape ({n}, d) to match "
                    f"cost, got shape {fs}")
        if is_concrete(w):
            import numpy as np
            if float(np.min(np.asarray(w))) < 0.0:
                raise ValueError("Geometry.weights must be non-negative")

    @classmethod
    def from_points(cls, points, weights, features=None, validate=True):
        """A point-cloud geometry: cost = squared euclidean distances,
        kept implicit so low-rank solvers can factor it exactly."""
        return cls(None, weights, features=features, points=points,
                   validate=validate)

    @property
    def n(self) -> int:
        if self.cost is not None:
            return self.cost.shape[0]
        return self.points.shape[0]

    @property
    def is_point_cloud(self) -> bool:
        """True when the geometry carries a point cloud (its squared
        euclidean cost factors exactly at rank d+2)."""
        return self.points is not None

    @property
    def cost_matrix(self):
        """The dense (n, n) cost — explicit, or assembled from the points.

        Point-cloud assembly is O(n²·d) and materializes the matrix the
        low-rank path exists to avoid; dense/spar/quantized solvers use it
        so every solver accepts every geometry.
        """
        if self.cost is not None:
            return self.cost
        import jax.numpy as jnp
        x = self.points
        sq = jnp.sum(x * x, axis=-1)
        D = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
        return jnp.maximum(D, 0.0)

    def content_hash(self) -> str:
        """Stable content digest of the geometry — the serving cache key.

        Hashes the *defining* arrays (dtype-, shape- and layout-stable:
        inputs are brought to C-contiguous host buffers first, so numpy
        vs jax arrays and C- vs F-ordered views of the same values hash
        equal). Construction-path invariant for a given representation:
        ``Geometry.from_points(p, w)`` and ``Geometry(None, w, points=p)``
        hash equal. A point-cloud geometry is hashed through its points —
        the implied n×n cost is **never materialized** — so it deliberately
        hashes differently from a geometry built from the densified cost:
        the two back different artifact families (factored vs dense), and
        establishing value equality would require the very O(n²)
        materialization the point-cloud path exists to avoid.

        Host-side only (raises on tracers); memoized on the instance, so
        repeated cache lookups for the same object pay the O(bytes) sha256
        once.
        """
        cached = getattr(self, "_content_hash", None)
        if cached is not None:
            return cached
        import hashlib

        import numpy as np
        if not all(is_concrete(x) for x in
                   (self.cost, self.weights, self.features, self.points)
                   if x is not None):
            raise ValueError(
                "Geometry.content_hash needs concrete arrays; it is a "
                "host-side cache key, not a traceable function")
        h = hashlib.sha256()

        def feed(tag: bytes, arr):
            if arr is None:
                h.update(tag + b":none;")
                return
            a = np.ascontiguousarray(np.asarray(arr))
            h.update(tag + b":" + str(a.dtype).encode()
                     + b":" + repr(a.shape).encode() + b";")
            h.update(a.tobytes())

        if self.cost is not None:
            feed(b"cost", self.cost)
            feed(b"pts", self.points)     # advisory, but still content
        else:
            feed(b"pts", self.points)
        feed(b"w", self.weights)
        feed(b"feat", self.features)
        digest = h.hexdigest()
        object.__setattr__(self, "_content_hash", digest)
        return digest


register_pytree_dataclass(Geometry, ("cost", "weights", "features", "points"))
