"""Shared tolerance-aware outer-loop driver for all GW solvers.

Replaces the fixed-length ``lax.scan`` outer loops: a bounded
``lax.while_loop`` that stops early once the coupling reaches a relative
ℓ1 fixed point, while recording the per-iteration marginal-violation
error into a fixed-size buffer (so the result has static shapes and the
whole solve stays ``jit``/``vmap``-compatible).

vmap semantics: ``lax.while_loop`` under ``vmap`` keeps stepping every
lane until *all* lanes are done, so the body freezes finished lanes with
``where(done, old, new)`` — a lane that converged at iteration k returns
exactly its iteration-k state no matter how long its batch peers run.

``tol <= 0`` reproduces the legacy fixed-budget behavior exactly: the
early-stop predicate is compiled out, the loop always runs the full
``max_iters``, and ``converged`` stays False.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax

_TINY = 1e-30


def _tree_l1(tree):
    return jax.tree.reduce(
        lambda acc, leaf: acc + jnp.sum(jnp.abs(leaf)), tree, jnp.float32(0))


def pga_loop(step_fn: Callable, err_fn: Callable, T0, max_iters: int,
             tol: float) -> Tuple:
    """Iterate ``T <- step_fn(T)`` up to ``max_iters`` times.

    step_fn — one outer PGA/entropic step (Sinkhorn projection included)
    err_fn  — diagnostic recorded per iteration (marginal ℓ1 violation)
    tol     — stop when sum|T_new - T| / sum|T| <= tol (static float),
              with the sums taken over every leaf when the iterate is a
              pytree (e.g. the (Q, R, g) factor triple of a low-rank
              coupling) — a single-array iterate reduces to the legacy
              scalar criterion bitwise

    Returns ``(T, errors, n_iters, converged)`` with ``errors`` of static
    shape (max_iters,), NaN-padded past ``n_iters``.
    """
    errs0 = jnp.full((max_iters,), jnp.nan, jnp.float32)
    if max_iters <= 0:
        return T0, errs0, jnp.int32(0), jnp.bool_(False)

    def cond(state):
        i, _, _, done = state
        return (i < max_iters) & jnp.logical_not(done)

    def body(state):
        i, T, errs, done = state
        T_new = step_fn(T)
        err = err_fn(T_new).astype(jnp.float32)
        # freeze lanes that were already done (batched-while masking)
        errs = jnp.where(done, errs, errs.at[i].set(err))
        T_out = jax.tree.map(lambda new, old: jnp.where(done, old, new),
                             T_new, T)
        i_out = jnp.where(done, i, i + 1)
        if tol > 0:                    # tol is static: predicate compiled out
            num = _tree_l1(jax.tree.map(lambda new, old: new - old, T_new, T))
            delta = num / jnp.maximum(_tree_l1(T), _TINY)
            done = done | (delta <= tol)
        return i_out, T_out, errs, done

    state0 = (jnp.int32(0), T0, errs0, jnp.bool_(False))
    n_iters, T, errors, converged = lax.while_loop(cond, body, state0)
    return T, errors, n_iters, converged
