"""Shared tolerance-aware outer-loop driver for all GW solvers.

A bounded ``lax.while_loop`` that stops early once the coupling reaches a
relative ℓ1 fixed point, while recording the per-iteration
marginal-violation error into a fixed-size buffer (static shapes, so the
whole solve stays ``jit``/``vmap``-compatible). Since the health layer
landed, the implementation lives in ``repro.health.loop.health_loop`` —
this module keeps the solver-facing name and re-exports the pieces
solvers consume.

vmap semantics: ``lax.while_loop`` under ``vmap`` keeps stepping every
lane until *all* lanes are done, so the body freezes finished lanes with
``where(done, old, new)`` — a lane that converged (or died) at iteration
k returns exactly its iteration-k state no matter how long its batch
peers run.

``tol <= 0`` reproduces the legacy fixed-budget behavior: the early-stop
predicate is compiled out, the loop runs the full ``max_iters`` (minus
nothing — rescues share the budget), and ``converged`` stays False.

Health semantics (repro/health/loop.py): every step's output is checked
for non-finite leaves and mass collapse; unhealthy steps are either
rescued (restart from the last healthy iterate with escalated
``scale``, when ``max_rescues > 0`` and ``scaled_step`` steps accept the
escalation) or end the lane with a DIVERGED status. The returned
:class:`~repro.health.loop.LoopResult` carries a per-lane
:class:`~repro.health.status.SolveStatus`.

Differentiation (repro/diff/fixed_point.py, DESIGN.md §11): the loop is
wrapped in a Danskin-envelope ``custom_vjp`` that declares the returned
fixed point locally constant in the problem data, so ``jax.grad`` of a
solver's post-loop value recomputation yields the implicit gradient in
one cost contraction — no unrolling, no per-solver code. Primal
numerics are unchanged.
"""
from __future__ import annotations

from typing import Callable

from repro.diff.fixed_point import envelope_loop
from repro.health.loop import LoopResult, health_loop

__all__ = ["pga_loop", "LoopResult", "health_loop"]


def pga_loop(step_fn: Callable, err_fn: Callable, T0, max_iters: int,
             tol: float, **health_kw) -> LoopResult:
    """Iterate ``T <- step_fn(T)`` up to ``max_iters`` times.

    step_fn — one outer PGA/entropic step (Sinkhorn projection included);
              with ``scaled_step=True`` it must accept ``(T, scale)``
              where ``scale`` is the ε-rescue escalation factor
    err_fn  — diagnostic recorded per iteration (marginal ℓ1 violation)
    tol     — stop when sum|T_new - T| / sum|T| <= tol (static float),
              with the sums taken over every leaf when the iterate is a
              pytree (e.g. the (Q, R, g) factor triple of a low-rank
              coupling)

    Extra keyword arguments (``scaled_step``, ``max_rescues``,
    ``rescue_factor``, ``mass_floor``, ``stall_err``, ``fault``,
    ``trace``, ``obj_fn``) are forwarded to
    :func:`repro.health.loop.health_loop`.

    Returns a ``LoopResult(iterate, errors, n_iters, converged, status,
    trace)`` with ``errors`` of static shape (max_iters,), NaN-padded past
    ``n_iters`` and at rescued/diverged iterations; ``trace`` is None
    unless ``trace=True`` was passed.

    Reverse-mode AD treats the whole result as locally constant (the
    Danskin envelope — repro/diff/fixed_point.py), which is exactly the
    implicit gradient once the caller recomputes its value from live
    data at the returned fixed point.
    """
    return envelope_loop(step_fn, err_fn, T0, max_iters, tol, **health_kw)
