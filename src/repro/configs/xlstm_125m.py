"""xlstm-125m [ssm] — 12L d768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM blocks.

Block pattern ``(mlstm, mlstm, mlstm, slstm) × 3`` (mLSTM-dominant, per the
xLSTM paper's [7:1]-style mostly-mLSTM configurations). d_ff=0 per assignment:
blocks carry their own up/down projections (``lstm_proj_factor``). Constant
state size ⇒ supports ``long_500k``.
"""
from repro.configs.base import ArchConfig, scale_down

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    n_superblocks=3,
    lstm_proj_factor=2.0,
    supports_long_context=True,
)


def reduced() -> ArchConfig:
    return scale_down(
        CONFIG, n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        vocab_size=256, n_superblocks=1,
    )
