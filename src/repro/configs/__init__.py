from repro.configs.base import (
    ARCH_IDS,
    CLI_ALIASES,
    SHAPES,
    ArchConfig,
    ShapeConfig,
    get_arch,
    get_reduced,
    shapes_for,
)
from repro.configs.paper import DEFAULT as DEFAULT_GW_CONFIG
from repro.configs.paper import GWSolverConfig
