"""minicpm3-4b [dense] — 62L d2560 40H d_ff=6400 vocab=73448 — MLA attention.

Multi-head latent attention dims follow hf:openbmb/MiniCPM3-4B:
q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64, qk_rope_head_dim=32,
v_head_dim=64. The KV cache stores the compressed latent (c_kv + k_rope).
"""
from repro.configs.base import ArchConfig, scale_down

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=96,          # qk head dim = nope 64 + rope 32
    d_ff=6400,
    vocab_size=73448,
    attn_type="mla",
    mla_q_rank=768,
    mla_kv_rank=256,
    mla_rope_dim=32,
    mla_nope_dim=64,
    mla_v_dim=64,
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return scale_down(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=24,
        d_ff=128, vocab_size=256, mla_q_rank=32, mla_kv_rank=16,
        mla_rope_dim=8, mla_nope_dim=16, mla_v_dim=16,
    )
