"""zamba2-7b [hybrid] — 81L d3584 32H d_ff=14336 vocab=32000, ssm_state=64.

Mamba2 backbone with one *shared* attention+MLP transformer block invoked
every 6 Mamba2 layers (13 invocations over 78 scanned layers + 3 tail Mamba2
layers = 81 SSM layers), Zamba2 style. The shared block's weights are a single
copy reused at every invocation. SSM state is O(1) in context ⇒ supports
``long_500k``.
"""
from repro.configs.base import ArchConfig, scale_down

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=("mamba2",) * 6,
    n_superblocks=13,
    tail_blocks=("mamba2",) * 3,
    shared_block_every=6,
    ssm_state=64,
    ssm_expand=2,
    ssm_heads=112,                  # d_inner 7168 / ssd head dim 64
    ssm_chunk=128,                  # VMEM/HBM-sized intra-chunk blocks
    supports_long_context=True,
)


def reduced() -> ArchConfig:
    return scale_down(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, block_pattern=("mamba2",) * 2,
        n_superblocks=2, tail_blocks=("mamba2",), shared_block_every=2,
        ssm_state=16, ssm_heads=4, ssm_chunk=8,
    )
