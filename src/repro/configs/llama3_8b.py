"""llama3-8b [dense] — 32L d4096 32H (GQA kv=8) d_ff=14336 vocab=128256."""
from repro.configs.base import ArchConfig, scale_down

CONFIG = ArchConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
)


def reduced() -> ArchConfig:
    return scale_down(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
    )
