"""Solver configs for the paper's own experiments (SPAR-GW and variants)."""
from dataclasses import dataclass


@dataclass(frozen=True)
class GWSolverConfig:
    loss: str = "l2"            # l1 | l2 | kl
    reg: str = "prox"           # prox (PGA, KL(T||T^r)) | ent (entropic H(T))
    epsilon: float = 1e-2
    outer_iters: int = 20       # R
    inner_iters: int = 50       # H (Sinkhorn)
    # sparsification
    sample_ratio: int = 16      # s = sample_ratio * n (paper default s = 16n)
    # unbalanced
    marginal_lambda: float = 1.0
    seed: int = 0


DEFAULT = GWSolverConfig()
PAPER_FIG2 = GWSolverConfig(epsilon=1e-2, outer_iters=20, inner_iters=50, sample_ratio=16)
