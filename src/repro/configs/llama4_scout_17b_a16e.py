"""llama4-scout-17b-a16e [moe] — 48L d5120 40H (GQA kv=8) d_ff=8192 vocab=202048.

MoE: 16 routed experts, top-1 routing, plus a shared expert per layer
(Llama-4-Scout style). Experts are sharded over the ``model`` axis (EP).
"""
from repro.configs.base import ArchConfig, scale_down

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=("moe",),
    n_experts=16,
    experts_per_token=1,
    shared_expert=True,
    rope_theta=500000.0,
)


def reduced() -> ArchConfig:
    return scale_down(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=256, n_experts=4, experts_per_token=1,
    )
