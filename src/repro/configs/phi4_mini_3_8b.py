"""phi4-mini-3.8b [dense] — 32L d3072 24H (GQA kv=8) d_ff=8192 vocab=200064."""
from repro.configs.base import ArchConfig, scale_down

CONFIG = ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    rope_theta=10000.0,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return scale_down(
        CONFIG, n_layers=2, d_model=48, n_heads=3, n_kv_heads=1, head_dim=16,
        d_ff=96, vocab_size=256,
    )
