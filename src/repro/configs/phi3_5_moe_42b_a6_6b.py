"""phi3.5-moe-42b-a6.6b [moe] — 32L d4096 32H (GQA kv=8) d_ff=6400 vocab=32064.

MoE: 16 experts, top-2 routing, no shared expert.
"""
from repro.configs.base import ArchConfig, scale_down

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    block_pattern=("moe",),
    n_experts=16,
    experts_per_token=2,
    shared_expert=False,
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return scale_down(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=256, n_experts=4, experts_per_token=2,
    )
