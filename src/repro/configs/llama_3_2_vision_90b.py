"""llama-3.2-vision-90b [vlm] — 100L d8192 64H (GQA kv=8) d_ff=28672 vocab=128256.

Cross-attention image layers every 5th layer (20 xattn superblock closers).
Modality frontend is a stub: ``input_specs`` provides precomputed patch
embeddings ``(batch, n_image_tokens, d_model)``.
"""
from repro.configs.base import ArchConfig, scale_down

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    block_pattern=("attn", "attn", "attn", "attn", "xattn"),
    n_superblocks=20,
    cross_attn_every=5,
    n_image_tokens=1024,
    rope_theta=500000.0,
)


def reduced() -> ArchConfig:
    return scale_down(
        CONFIG,
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        n_superblocks=1,
        n_image_tokens=8,
    )
