"""Architecture / shape / run configuration dataclasses.

Every assigned architecture gets a module ``repro/configs/<id>.py`` exporting
``CONFIG`` (full-size, dry-run only) and ``reduced()`` (CPU smoke-test size).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    """A decoder-only LM backbone configuration.

    ``block_pattern`` describes one *superblock*; the stack is
    ``n_superblocks`` repetitions (scanned) plus ``tail_blocks`` extra
    blocks. Block kinds: ``attn`` (self-attn + MLP), ``xattn`` (cross-attn +
    MLP), ``mamba2``, ``mlstm``, ``slstm``, ``moe`` (self-attn + MoE MLP).
    """

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # superblock structure
    block_pattern: Tuple[str, ...] = ("attn",)
    n_superblocks: int = 0           # 0 -> n_layers // len(block_pattern)
    tail_blocks: Tuple[str, ...] = ()
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # MLA (multi-head latent attention)
    attn_type: str = "gqa"           # gqa | mla
    mla_q_rank: int = 0
    mla_kv_rank: int = 0
    mla_rope_dim: int = 0
    mla_nope_dim: int = 0
    mla_v_dim: int = 0
    # SSM
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_heads: int = 0               # mamba2 value heads
    ssm_chunk: int = 256
    # hybrid (zamba2): shared transformer block invoked every k ssm layers
    shared_block_every: int = 0
    # xLSTM
    lstm_proj_factor: float = 2.0
    # VLM
    cross_attn_every: int = 0        # informational; pattern encodes placement
    n_image_tokens: int = 0
    # audio
    n_codebooks: int = 0
    # misc
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # which shapes are defined for this arch (long_500k only for sub-quadratic)
    supports_long_context: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_superblocks(self) -> int:
        if self.n_superblocks:
            return self.n_superblocks
        return (self.n_layers - len(self.tail_blocks)) // len(self.block_pattern)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = (
    "llama_3_2_vision_90b",
    "llama3_8b",
    "smollm_135m",
    "minicpm3_4b",
    "phi4_mini_3_8b",
    "llama4_scout_17b_a16e",
    "phi3_5_moe_42b_a6_6b",
    "xlstm_125m",
    "zamba2_7b",
    "musicgen_medium",
)

# CLI ids (match assignment spelling) -> module names
CLI_ALIASES = {
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "llama3-8b": "llama3_8b",
    "smollm-135m": "smollm_135m",
    "minicpm3-4b": "minicpm3_4b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "xlstm-125m": "xlstm_125m",
    "zamba2-7b": "zamba2_7b",
    "musicgen-medium": "musicgen_medium",
}


def get_arch(name: str) -> ArchConfig:
    mod_name = CLI_ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_reduced(name: str) -> ArchConfig:
    mod_name = CLI_ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.reduced()


def shapes_for(arch: ArchConfig):
    """The assigned shape cells that are active for this architecture."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not arch.supports_long_context:
            continue  # skip documented in DESIGN.md §Arch-applicability
        out.append(s)
    return out


def scale_down(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Build a reduced config of the same family for CPU smoke tests."""
    return dataclasses.replace(cfg, **overrides)
