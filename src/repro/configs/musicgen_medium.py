"""musicgen-medium [audio] — 48L d1536 24H d_ff=6144 vocab=2048.

Decoder-only transformer over EnCodec tokens: 4 codebooks, embeddings summed
at the input (delay-pattern handling lives in the data pipeline / stub
frontend per the assignment), 4 parallel LM heads at the output.
"""
from repro.configs.base import ArchConfig, scale_down

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    n_codebooks=4,
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return scale_down(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=64, n_codebooks=2,
    )
