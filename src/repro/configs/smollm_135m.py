"""smollm-135m [dense] — 30L d576 9H (GQA kv=3) d_ff=1536 vocab=49152."""
from repro.configs.base import ArchConfig, scale_down

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab_size=49152,
    rope_theta=10000.0,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return scale_down(
        CONFIG, n_layers=3, d_model=48, n_heads=3, n_kv_heads=1, head_dim=16,
        d_ff=96, vocab_size=256,
    )
