"""Importance-sparsified Gromov-Wasserstein distances in JAX.

Public API: build a :class:`~repro.QuadraticProblem` from two
:class:`~repro.Geometry` objects and call :func:`repro.solve` with a
solver config (or none — the solver is auto-selected from the problem
structure). The per-variant functions in ``repro.core`` (``spar_gw``,
``gw_dense``, ...) remain available as deprecation shims over this layer.
"""
from repro.api import (
    DenseGWSolver,
    Geometry,
    GridCoupling,
    GridGWSolver,
    GWOutput,
    LowRankCoupling,
    LowRankGWSolver,
    QuadraticProblem,
    QuantizedCoupling,
    QuantizedGWSolver,
    SparGWSolver,
    SparseCoupling,
    available_solvers,
    get_solver,
    register_solver,
    select_solver,
    solve,
)
from repro import diff, obs  # noqa: E402  (after api: diff closes the loop)

__all__ = [
    "diff",
    "obs",
    "Geometry",
    "QuadraticProblem",
    "GWOutput",
    "SparseCoupling",
    "GridCoupling",
    "QuantizedCoupling",
    "LowRankCoupling",
    "solve",
    "select_solver",
    "SparGWSolver",
    "DenseGWSolver",
    "GridGWSolver",
    "QuantizedGWSolver",
    "LowRankGWSolver",
    "get_solver",
    "register_solver",
    "available_solvers",
]
