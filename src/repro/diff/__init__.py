"""``repro.diff`` — implicit differentiation of GW solves.

Makes ``repro.solve(...).value`` a trainable loss: the fixed-point
driver carries a Danskin/envelope ``custom_vjp`` (fixed_point.py), so
``jax.grad`` through a solve costs one cost-gradient contraction
instead of unrolling the outer loop. On top of that sit

* :func:`~repro.diff.losses.gw_loss` / :func:`~repro.diff.losses.
  fgw_loss` — jit+grad+vmap-composable scalar losses;
* :func:`~repro.diff.barycenter.gw_barycenter` — free-support GW
  barycenters by AdamW descent on the support;
* :mod:`repro.diff.unrolled` — unrolled-autodiff reference
  implementations (the correctness/cost baseline, not the product).

``fixed_point`` is imported eagerly (``api/driver`` needs it at import
time); the loss/barycenter layers load lazily to keep the
driver → diff → losses → api.solve import cycle open.
"""
from __future__ import annotations

from repro.diff.fixed_point import envelope_loop, locally_constant

__all__ = [
    "envelope_loop",
    "locally_constant",
    "gw_loss",
    "fgw_loss",
    "quadratic_loss",
    "gw_barycenter",
    "BarycenterResult",
]

_LAZY = {
    "gw_loss": "repro.diff.losses",
    "fgw_loss": "repro.diff.losses",
    "quadratic_loss": "repro.diff.losses",
    "gw_barycenter": "repro.diff.barycenter",
    "BarycenterResult": "repro.diff.barycenter",
}


def __getattr__(name):  # PEP 562
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.diff' has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
