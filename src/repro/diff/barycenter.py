"""Free-support GW barycenters by gradient descent on the support.

A barycenter of K measured spaces (Y_1, w_1), …, (Y_K, w_K) is a point
cloud X minimizing

    B(X) = Σ_k ω_k · GW(X, Y_k)

over the support coordinates X ∈ ℝ^{n×d} (uniform weights on X). With
the Danskin envelope on the solver driver, ∇B is K implicit gradients —
one cost contraction per space, no unrolling — so the whole thing is
AdamW (optim/adamw.py, ``weight_decay=0``: shrinking coordinates toward
the origin is meaningless for a support) on a jitted value-and-grad.

GW is invariant to isometries of X, so the minimizer is a *shape*, not
a pose: expect the objective, not the coordinates, to be reproducible
across seeds. The objective trajectory is recorded per step and ships
in :class:`BarycenterResult` — the CI smoke asserts a monotone descent
on a fixed seed (see benchmarks/bench_diff.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.api.geometry import Geometry
from repro.diff.losses import _as_geometry, _uniform, quadratic_loss
from repro.optim import adamw

__all__ = ["gw_barycenter", "BarycenterResult"]


class BarycenterResult(NamedTuple):
    points: Any        # (n_points, dim) learned support
    objectives: Any    # (steps + 1,) B(X) before each step + final
    grad_norms: Any    # (steps,) global grad norm per step


def _init_support(key, geoms: Sequence[Geometry], n_points: int,
                  dim: Optional[int]):
    """Random init scaled to the inputs: points drawn N(0, I)·scale with
    scale matched to the first point cloud's RMS radius (or the RMS
    pairwise cost for precomputed geometries), so the first solves start
    at a comparable cost magnitude instead of a degenerate near-zero
    blob."""
    pts = next((g.points for g in geoms if g.points is not None), None)
    if dim is None:
        if pts is None:
            raise ValueError(
                "gw_barycenter needs dim= when no input geometry carries "
                "points (precomputed-cost inputs don't fix an embedding "
                "dimension)")
        dim = pts.shape[1]
    if pts is not None:
        scale = jnp.sqrt(jnp.mean(jnp.sum(
            (pts - pts.mean(axis=0)) ** 2, axis=-1)) / dim)
    else:
        scale = jnp.sqrt(jnp.mean(geoms[0].cost_matrix) / (2.0 * dim))
    return scale * jax.random.normal(key, (n_points, dim))


def gw_barycenter(geometries: Sequence[Union[Geometry, Any]],
                  n_points: int, key: jax.Array, *,
                  dim: Optional[int] = None,
                  weights: Optional[Sequence[float]] = None,
                  loss: str = "l2",
                  solver: Union[str, object, None] = None,
                  steps: int = 100, lr: float = 0.05,
                  b1: float = 0.9, b2: float = 0.99,
                  max_grad_norm: float = 1e6,
                  x0: Optional[Any] = None) -> BarycenterResult:
    """Descend ``Σ_k ω_k GW(X, Y_k)`` over a free support X.

    geometries — input spaces: Geometry instances or (n_k, d_k) point
                 clouds (dimensions may differ across inputs — that is
                 the point of GW)
    n_points   — barycenter support size
    key        — PRNG key: support init + per-input solver keys (each
                 input gets a fixed ``fold_in`` sub-key, so sampled
                 supports stay frozen across descent steps and the loss
                 surface is deterministic)
    solver     — forwarded to :func:`repro.diff.losses.quadratic_loss`
                 (None auto-selects per input from problem structure)
    x0         — explicit (n_points, dim) init, overriding the random
                 scaled init

    Returns :class:`BarycenterResult`; ``objectives`` has the pre-step
    objective at index 0 — ``objectives[-1]`` is the final value, and a
    well-tuned ``lr`` descends monotonically (asserted by the CI smoke).
    """
    geoms = [_as_geometry(g) for g in geometries]
    if weights is None:
        omega = jnp.full((len(geoms),), 1.0 / len(geoms))
    else:
        omega = jnp.asarray(weights)
        omega = omega / jnp.sum(omega)
    key_init, key_solve = jax.random.split(key)
    X = x0 if x0 is not None else _init_support(key_init, geoms, n_points,
                                                dim)
    a = _uniform(n_points, X)
    sub_keys = [jax.random.fold_in(key_solve, k) for k in range(len(geoms))]

    def objective(X_):
        geom_x = Geometry.from_points(X_, a, validate=False)
        total = 0.0
        for w_k, geom_k, key_k in zip(omega, geoms, sub_keys):
            from repro.api.problem import QuadraticProblem
            problem = QuadraticProblem(geom_x, geom_k, loss=loss,
                                       validate=False)
            total = total + w_k * quadratic_loss(problem, solver, key_k)
        return total

    @jax.jit
    def step_fn(X_, opt_state):
        value, grads = jax.value_and_grad(objective)(X_)
        X_new, opt_state, gnorm = adamw.update(
            grads, opt_state, X_, lr, b1=b1, b2=b2, weight_decay=0.0,
            max_grad_norm=max_grad_norm)
        return X_new, opt_state, value, gnorm

    opt_state = adamw.init(X)
    objectives, grad_norms = [], []
    for _ in range(steps):
        X, opt_state, value, gnorm = step_fn(X, opt_state)
        objectives.append(value)    # objective at the *pre-update* X
        grad_norms.append(gnorm)
    final = objective(X)
    return BarycenterResult(points=X,
                            objectives=jnp.stack(objectives + [final]),
                            grad_norms=jnp.stack(grad_norms))
