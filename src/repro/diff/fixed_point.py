"""Envelope (Danskin) differentiation of the fixed-point driver.

The outer loop of every solver is a ``lax.while_loop`` —
forward-differentiable, but *not* reverse-differentiable, and even where
unrolling is possible it costs O(iters) memory and wall time on the
backward pass. This module makes the loop reverse-differentiable at the
cost of **one** cost-gradient contraction by exploiting the envelope
structure of the plug-in GW estimate:

Every solver computes its reported ``value`` *after* the loop, from live
(differentiable) problem data and the returned coupling — e.g.
``gw_objective(Cx, Cy, T*, loss)`` for dense, ``Σ T*·cost(T*)`` on the
COO support for spar, ``gw_lr_value(Q, R, g, fx, fy)`` for low-rank. At
a converged proximal / mirror-descent fixed point, ``T*`` is a
stationary point of the objective ``F`` over the coupling polytope, so
by Danskin's theorem

    dV/dθ = ∂F(θ, T)/∂θ |_{T = T*}          (T* locally constant in θ)

— the coupling's own sensitivity ``dT*/dθ`` contributes nothing. The
implementation therefore declares the whole loop **locally constant**: a
``jax.custom_vjp`` whose backward pass returns zero cotangents for every
input, so reverse-mode AD flows only through the post-loop value
recomputation. That single contraction *is* the Danskin gradient.

The subtlety is that solvers hand the driver *closures* (``step_fn``,
``err_fn``, ``obj_fn``) that capture problem data as tracers; a
``custom_vjp`` cannot see through captured tracers
(``CustomVJPException``). :func:`_closure_convert_all` hoists every
captured value — inexact *and* integer — into explicit operands, which
then receive the zero (or ``float0``) cotangents like everything else.

Guarantees (tested by tests/test_diff.py and the tier-1 suite):

* primal numerics are bitwise-unchanged — ``closure_convert`` replays
  the very jaxpr the closure would have produced;
* health semantics (ε-rescues, fault injection, ``trace=True``) pass
  through untouched: the envelope wraps the *health-instrumented* loop,
  and a rescue that fires inside the loop changes which fixed point is
  reached, never how it is differentiated;
* composes with ``jit``, ``vmap``-of-``grad`` and ``grad``-of-``vmap``.

Forward-mode (``jax.jvp``) through the loop is intentionally cut along
with reverse mode — ``custom_vjp`` supports reverse only. Nothing in the
repo used forward-mode through a solve; the loss surface in
``diff/losses.py`` is the supported entry point.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.health.loop import LoopResult, health_loop

__all__ = ["envelope_loop", "locally_constant"]


def _zero_cotangent(x):
    """A zero cotangent matching ``x``: dense zeros for inexact dtypes,
    ``float0`` for integer/bool leaves (the only cotangent JAX accepts
    for non-differentiable dtypes, e.g. a FaultSpec's ``at_iter``)."""
    aval = jax.core.get_aval(x)
    if jnp.issubdtype(aval.dtype, jnp.inexact):
        return jnp.zeros(aval.shape, aval.dtype)
    return np.zeros(aval.shape, dtype=jax.dtypes.float0)


class _StaticFn:
    """Identity-hashed wrapper so a Python callable can ride in a
    ``custom_vjp`` nondiff argument slot."""

    __slots__ = ("fn",)

    def __init__(self, fn: Callable):
        self.fn = fn

    def __hash__(self):
        return id(self.fn)

    def __eq__(self, other):
        return isinstance(other, _StaticFn) and other.fn is self.fn


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _const_call(static: _StaticFn, operands: tuple):
    return static.fn(*operands)


def _const_call_fwd(static, operands):
    return _const_call(static, operands), operands


def _const_call_bwd(static, operands, _cotangent):
    return (jax.tree.map(_zero_cotangent, operands),)


_const_call.defvjp(_const_call_fwd, _const_call_bwd)


def locally_constant(fn: Callable, *operands):
    """Run ``fn(*operands)`` declaring the result locally constant in
    every operand: the primal is unchanged, reverse-mode AD sees zero
    gradients through this call. ``fn`` must not capture tracers — pass
    everything traced through ``operands`` (use ``jax.closure_convert``
    to hoist captured values first)."""
    return _const_call(_StaticFn(fn), operands)


def _closure_convert_all(fn: Callable, *example_args):
    """:func:`jax.closure_convert`, except *every* captured tracer is
    hoisted into an explicit operand — including the integer / bool /
    PRNG-key captures (spar's sampled support indices, keys) that
    ``closure_convert`` leaves baked into the jaxpr as constants
    (it only hoists perturbable inexact dtypes). A baked tracer
    constant survives eager grad and jit-of-grad, where the enclosing
    trace is still live when the jaxpr is consumed, but breaks
    grad-of-jit: the pjit forward is compiled after its trace closes,
    and an executable cannot take a dead trace's tracer as a constant.
    Hoisted integer operands receive ``float0`` cotangents from
    :func:`_zero_cotangent` like everything else."""
    flat, in_tree = jax.tree.flatten(tuple(example_args))

    def flat_fn(*flat_args):
        return fn(*jax.tree.unflatten(in_tree, flat_args))

    closed, out_shape = jax.make_jaxpr(flat_fn, return_shape=True)(*flat)
    out_tree = jax.tree.structure(out_shape)
    jaxpr, n_consts = closed.jaxpr, len(closed.consts)

    def converted(*args_then_consts):
        if n_consts:
            args = args_then_consts[:-n_consts]
            hoisted = args_then_consts[-n_consts:]
        else:
            args, hoisted = args_then_consts, ()
        flat_args, _ = jax.tree.flatten(tuple(args))
        out = jax.core.eval_jaxpr(jaxpr, list(hoisted), *flat_args)
        return jax.tree.unflatten(out_tree, out)

    return converted, list(closed.consts)


# example aval for the rescue-escalation scalar handed to scaled steps;
# must match what health_loop passes (f32 regardless of x64 mode):
# ``jnp.float32(rescue_factor) ** n_rescues``
def _scale_example():
    return jnp.float32(1.0)


def envelope_loop(step_fn: Callable, err_fn: Callable, T0, max_iters: int,
                  tol: float, **health_kw) -> LoopResult:
    """Drop-in ``pga_loop`` with the Danskin envelope installed.

    Same contract as :func:`repro.health.loop.health_loop`; the returned
    :class:`LoopResult` is numerically identical but reverse-mode AD
    treats every field of it (iterate, errors, status, trace) as locally
    constant in the problem data. Solvers that recompute their value
    from live data after the loop — all of them — become differentiable
    for free; see the module docstring for why that gradient is the
    right one at a converged fixed point.
    """
    fault = health_kw.pop("fault", None)
    obj_fn = health_kw.pop("obj_fn", None)
    step_args = ((T0, _scale_example())
                 if health_kw.get("scaled_step", False) else (T0,))
    # hoist ALL tracer captures out of the solver closures — integer
    # captures (support indices, PRNG keys) included, or grad-of-jit
    # leaks them as dead-trace constants (see _closure_convert_all)
    step_c, step_hoisted = _closure_convert_all(step_fn, *step_args)
    err_c, err_hoisted = _closure_convert_all(err_fn, T0)
    if obj_fn is not None and health_kw.get("trace", False):
        obj_c, obj_hoisted = _closure_convert_all(obj_fn, T0)
    else:
        # without trace=True the loop never calls obj_fn — drop it so an
        # unconverted closure can't leak tracers into the custom_vjp
        obj_c, obj_hoisted = None, ()

    def run_loop(T0_, step_h, err_h, obj_h, fault_):
        sf = lambda *args: step_c(*args, *step_h)          # noqa: E731
        ef = lambda t: err_c(t, *err_h)                    # noqa: E731
        of = (lambda t: obj_c(t, *obj_h)) if obj_c is not None else None
        return health_loop(sf, ef, T0_, max_iters, tol, fault=fault_,
                           obj_fn=of, **health_kw)

    return locally_constant(run_loop, T0, tuple(step_hoisted),
                            tuple(err_hoisted), tuple(obj_hoisted), fault)
