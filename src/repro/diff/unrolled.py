"""Unrolled-autodiff reference: differentiate *through* the iterations.

The correctness and cost baseline for the envelope gradient
(fixed_point.py). Each solver family's outer loop is replayed as a
``lax.scan`` over a fixed budget with reverse-differentiable inner
solves, so plain ``jax.grad`` backpropagates through every iteration —
O(iters) backward wall time and O(iters × state) residual memory,
against the envelope's O(1) of each. tests/test_diff.py checks the two
gradients agree at converged fixed points; benchmarks/bench_diff.py
records how much the envelope saves at n ≥ 1000.

Faithfulness contract: given the same config and key, the unrolled
forward pass reproduces the production solver's fixed-budget trajectory
(same step math, same sampling, same init — spar reuses the *actual*
``_spar_pga_step``; lowrank reuses ``_md_step`` and the shared init
functions), restricted to the regime reverse-mode AD can handle:

* ``tol = 0`` semantics — the scan has no early stop; the production
  outer ``tol`` is ignored;
* ``inner_tol = 0`` required — a tolerance-stopped inner solve is a
  ``while_loop``, which reverse-mode AD rejects (raise, don't silently
  differ);
* no health instrumentation — rescues/faults don't exist here (a
  trajectory that needs rescuing is not a fixed point worth
  differentiating).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import sampling
from repro.core.gw import dense_cost, gw_objective
from repro.core.sinkhorn import sinkhorn_log
from repro.kernels.spar_cost.ops import make_spar_cost_fn

__all__ = ["unrolled_value"]


def _check_inner_tol(solver):
    if getattr(solver, "inner_tol", 0.0):
        raise ValueError(
            "unrolled_value needs inner_tol=0 (a tolerance-stopped inner "
            "solve is a while_loop — not reverse-differentiable); rebuild "
            f"the config: {type(solver).__name__}(..., inner_tol=0.0)")


def _dense_value(problem, solver):
    from repro.api.solvers import DenseGWSolver  # noqa: F401 — dispatch twin

    Cx, a = problem.geom_x.cost_matrix, problem.geom_x.weights
    Cy, b = problem.geom_y.cost_matrix, problem.geom_y.weights
    loss = problem.loss
    fused = problem.is_fused
    alpha = problem.fused_penalty if fused else 1.0
    M = problem.linear_cost_dense() if fused else None
    T0 = a[:, None] * b[None, :]

    def outer(T, _):
        C = dense_cost(Cx, Cy, T, loss)
        if fused:
            C = alpha * C + (1 - alpha) * M
        logK = -C / solver.epsilon
        if solver.reg == "prox":
            logK = logK + jnp.log(jnp.maximum(T, 1e-38))
        T = sinkhorn_log(a, b, logK, solver.inner_iters,
                         differentiable=True)
        return T, None

    T, _ = lax.scan(outer, T0, None, length=solver.outer_iters)
    quad = gw_objective(Cx, Cy, T, loss)
    if fused:
        return alpha * quad + (1 - alpha) * jnp.sum(M * T)
    return quad


def _spar_value(problem, solver, key):
    from repro.api.solvers import _spar_pga_step

    Cx, a = problem.geom_x.cost_matrix, problem.geom_x.weights
    Cy, b = problem.geom_y.cost_matrix, problem.geom_y.weights
    m, n = a.shape[0], b.shape[0]
    probs = sampling.balanced_probs(a, b, solver.shrink)
    rows, cols = sampling.sample_pairs(key, probs, solver.s)
    w = 1.0 / (solver.s * probs.pair_prob(rows, cols))
    T0 = a[rows] * b[cols]
    cost_fn = make_spar_cost_fn(Cx, Cy, rows, cols, problem.loss,
                                impl=solver.cost_impl,
                                chunk=solver.cost_chunk)
    fused = problem.is_fused
    alpha = problem.fused_penalty if fused else 1.0
    lin = problem.linear_cost_at(rows, cols) if fused else 0.0
    step = partial(_spar_pga_step, cost_fn=cost_fn, a=a, b=b, rows=rows,
                   cols=cols, w=w, logw=jnp.log(w), m=m, n=n,
                   epsilon=solver.epsilon, inner_iters=solver.inner_iters,
                   inner_tol=0.0, reg=solver.reg, stable=solver.stable,
                   alpha=alpha, lin=lin)

    def outer(T, _):
        return step(T, 1.0), None

    T, _ = lax.scan(outer, T0, None, length=solver.outer_iters)
    quad = jnp.sum(T * cost_fn(T))
    if fused:
        return alpha * quad + (1.0 - alpha) * jnp.sum(lin * T)
    return quad


def _lowrank_value(problem, solver, key):
    from repro.lowrank.factorize import factor_ground
    from repro.lowrank.gradients import gw_lr_value
    from repro.lowrank.init import anchor_init, random_init

    a = problem.geom_x.weights
    b = problem.geom_y.weights
    m, n = problem.shape
    rank, cost_rank = solver._resolve(m, n)
    key_init, key_fx, key_fy = jax.random.split(key, 3)
    fx = factor_ground(problem.geom_x, problem.loss, "x", cost_rank, key_fx)
    fy = factor_ground(problem.geom_y, problem.loss, "y", cost_rank, key_fy)
    if solver.init == "anchors":
        state0 = anchor_init(key_init, problem, rank,
                             blend=solver.init_blend)
    else:
        state0 = random_init(key_init, a, b, rank)
    # dykstra's tolerance knob rides on the solver config, not the step
    # signature — enforce the fixed budget the scan needs
    import dataclasses

    md = partial(dataclasses.replace(solver, inner_tol=0.0,
                                     fault=None)._md_step,
                 a=a, b=b, hx=fx.h, hy=fy.h)

    def outer(state, _):
        return md(state, jnp.float32(1.0)), None

    state, _ = lax.scan(outer, state0, None, length=solver.outer_iters)
    return gw_lr_value(state[0], state[1], state[2], fx, fy)


def unrolled_value(problem, solver, key: Optional[jax.Array] = None):
    """Solve ``problem`` with ``solver``'s fixed budget, differentiably,
    by unrolling the outer loop — returns the scalar plug-in value.

    Balanced problems only (the unbalanced steps add nothing to the
    comparison). Dispatches on the config type: DenseGWSolver,
    SparGWSolver (key required), LowRankGWSolver (key required).
    """
    from repro.api.solvers import DenseGWSolver, SparGWSolver
    from repro.lowrank.solver import LowRankGWSolver

    if problem.is_unbalanced:
        raise NotImplementedError(
            "unrolled_value covers balanced problems only")
    _check_inner_tol(solver)
    if isinstance(solver, DenseGWSolver):
        return _dense_value(problem, solver)
    if isinstance(solver, SparGWSolver):
        if key is None:
            raise ValueError("unrolled spar_gw needs the solver's PRNG key")
        return _spar_value(problem, solver, key)
    if isinstance(solver, LowRankGWSolver):
        if key is None:
            raise ValueError("unrolled lowrank_gw needs the PRNG key")
        return _lowrank_value(problem, solver, key)
    raise NotImplementedError(
        f"no unrolled reference for {type(solver).__name__}")
