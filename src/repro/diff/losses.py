"""``gw_loss`` / ``fgw_loss`` — GW solves as trainable losses.

Thin, composable wrappers over :func:`repro.solve`: the heavy lifting is
the Danskin envelope on the fixed-point driver (diff/fixed_point.py),
which makes ``solve(...).value`` reverse-differentiable w.r.t. every
inexact leaf of the problem — cost matrices, point clouds, fused
features / ``M``, ``fused_penalty``, ``lam``. These wrappers add the
ergonomics: build the problem from arrays, pick a solver, and (opt-in)
recover **marginal** gradients for balanced problems, where the
coupling-polytope constraint makes the plain envelope return zero.

All three losses compose with ``jax.jit``, ``jax.grad`` and
``jax.vmap`` in any order; see tests/test_diff.py.

What is differentiable, per family (DESIGN.md §11 has the derivation):

============  =========================================================
solver        differentiable w.r.t.
============  =========================================================
dense_gw      Cx, Cy (or points), M / features, ``fused_penalty``;
              ``lam`` and marginals for unbalanced problems (the KL
              penalty terms are *live* paths through the envelope —
              measured FD agreement ~1e-10); balanced marginals via
              ``marginal_grads=True`` — a **dual-certificate
              approximation**, see the caveat on
              :func:`quadratic_loss`
spar_gw       gathered Cx, Cy, features, ``fused_penalty``, ``lam``
              — **not** the marginals: the importance-sampled support
              is drawn from (a, b), a discrete, non-differentiable map
lowrank_gw    point clouds through the exact rank-(d+2) factors (and
              precomputed costs through the sketch), never forming an
              m×n object in either pass
============  =========================================================

Gradient quality is gated on *convergence*: Danskin's theorem holds at
a stationary point of the objective over the polytope, which the prox /
mirror-descent iterations reach but generous iteration budgets are
needed to reach it tightly (an unconverged solve yields a biased
gradient — see the budget guidance in EXPERIMENTS.md). ``reg="ent"``
fixed points are stationary for the *entropic* objective, so gradients
of the reported plug-in value carry an O(ε) bias there; prefer the
default ``reg="prox"`` when training.
"""
from __future__ import annotations

from typing import Any, Optional, Union

import jax
import jax.numpy as jnp

from repro.api.geometry import Geometry
from repro.api.problem import QuadraticProblem
from repro.core.gw import dense_cost

__all__ = ["gw_loss", "fgw_loss", "quadratic_loss"]


def _uniform(k: int, like) -> jnp.ndarray:
    dtype = jnp.result_type(like) if like is not None else jnp.float32
    return jnp.full((k,), 1.0 / k, dtype)


def _as_geometry(arr_or_geom, weights=None, features=None) -> Geometry:
    """Points array → point-cloud Geometry; Geometry passes through."""
    if isinstance(arr_or_geom, Geometry):
        return arr_or_geom
    pts = jnp.asarray(arr_or_geom)
    if pts.ndim != 2:
        raise ValueError(
            f"expected an (n, d) point cloud or a Geometry, got shape "
            f"{pts.shape}")
    w = _uniform(pts.shape[0], pts) if weights is None else weights
    return Geometry.from_points(pts, w, features=features, validate=False)


def quadratic_loss(problem: QuadraticProblem,
                   solver: Union[str, object, None] = None,
                   key: Optional[jax.Array] = None, *,
                   marginal_grads: bool = False):
    """Differentiable scalar GW value of a prebuilt problem.

    The general entry point — ``gw_loss`` / ``fgw_loss`` build the
    problem for you. ``solver`` follows :func:`repro.solve` semantics
    (config instance, registry name, or None for auto-selection).

    marginal_grads — attach *balanced* marginal gradients by adding a
    primal-zero dual correction (the value is unchanged; gradients
    w.r.t. the weight vectors become dual potentials of the linearized
    problem, recovered by a coupling-weighted least squares on
    ∇F(T*) ≈ f ⊕ g). Dense prox solves only; for unbalanced problems
    marginal gradients flow through the KL penalty terms automatically
    (and exactly) and this flag must stay False.

    **Caveat (balanced only).** The recovery is exact when the
    converged coupling is strictly interior (or its support is
    connected and stable under the perturbation). Prox fixed points of
    near-isometric problems are permutation-like — there a zero-sum
    reweighting forces the *support itself* to move, the computed
    value's sensitivity is budget-dependent, and no local recovery
    reproduces finite differences (measured here; see DESIGN.md §11).
    Treat the result as a descent *certificate direction*, or switch to
    an unbalanced formulation (``lam``) whose marginal gradients are
    exact. Gradients are meaningful for zero-sum perturbations only —
    the tangent space of the probability simplex.
    """
    from repro.api.solve import select_solver, solve
    from repro.api.solvers import DenseGWSolver, get_solver

    if solver is None:
        solver = select_solver(problem)
    elif isinstance(solver, str):
        solver = get_solver(solver).default_config(max(problem.shape))
    out = solve(problem, solver, key, validate=False)
    value = out.value
    if marginal_grads:
        if problem.is_unbalanced:
            raise ValueError(
                "marginal_grads=True is for balanced problems; unbalanced "
                "marginal gradients already flow through the KL penalties")
        if not isinstance(solver, DenseGWSolver) or solver.reg != "prox":
            raise ValueError(
                "marginal_grads=True needs a dense prox solve (the dual "
                "recovery reads the full coupling at a true stationary "
                f"point); got {type(solver).__name__}"
                f"(reg={getattr(solver, 'reg', None)!r})")
        value = value + _marginal_dual_correction(problem, out.coupling)
    return value


def _marginal_dual_correction(problem: QuadraticProblem, T,
                              sweeps: int = 100):
    """Primal-zero term whose gradient w.r.t. (a, b) is the dual pair.

    At an exact prox fixed point the objective gradient ``A = ∇F(T*)``
    satisfies ``A_ij = f_i + g_j`` on the *settled* support of T* (the
    kernel exponent of the self-consistent Sinkhorn projection is a
    rank-one sum there; entries still sliding to zero never settle and
    obey an inequality instead). The potentials are therefore recovered
    by coupling-weighted least squares

        min_{f, g}  Σ_ij T*_ij (A_ij − f_i − g_j)²

    via its alternating normal equations (each sweep is two weighted
    row/column averages — a Laplacian Jacobi pass that converges
    geometrically for connected supports). The envelope theorem then
    gives dV/da = f, dV/db = g along zero-sum directions, and the
    correction ⟨f, a − sg(a)⟩ + ⟨g, b − sg(b)⟩ is exactly zero in the
    primal while injecting those gradients. Exactness caveats:
    :func:`quadratic_loss`.
    """
    sg = jax.lax.stop_gradient
    a, b = problem.geom_x.weights, problem.geom_y.weights
    Cx = problem.geom_x.cost_matrix
    Cy = problem.geom_y.cost_matrix
    A = 2.0 * dense_cost(Cx, Cy, T, problem.loss)
    if problem.is_fused:
        alpha = problem.fused_penalty
        A = alpha * A + (1.0 - alpha) * problem.linear_cost_dense()
    A, T = sg(A), sg(T)
    mu = jnp.maximum(T.sum(axis=1), 1e-30)
    nu = jnp.maximum(T.sum(axis=0), 1e-30)
    TA = T * A

    def sweep(_, fg):
        f, g = fg
        f = (TA.sum(axis=1) - T @ g) / mu
        g = (TA.sum(axis=0) - T.T @ f) / nu
        return f, g

    f, g = jax.lax.fori_loop(0, sweeps, sweep,
                             (jnp.zeros_like(mu), jnp.zeros_like(nu)))
    # gauge fix: split the shared constant evenly (irrelevant for
    # zero-sum tangents, keeps the pair symmetric for inspection)
    s = 0.5 * (f @ sg(jnp.asarray(a) / jnp.sum(a)) -
               g @ sg(jnp.asarray(b) / jnp.sum(b)))
    return (jnp.sum((f - s) * (a - sg(a)))
            + jnp.sum((g + s) * (b - sg(b))))


def gw_loss(x, y, a=None, b=None, *, loss: str = "l2",
            solver: Union[str, object, None] = None,
            key: Optional[jax.Array] = None,
            marginal_grads: bool = False):
    """GW distance between two spaces as a differentiable loss.

    x, y — (m, d) / (n, d') point clouds (gradients flow into the
    coordinates) or :class:`Geometry` instances (gradients flow into
    whatever inexact leaves they carry, e.g. a precomputed cost matrix)
    a, b — optional marginals (uniform when omitted)

    Example — embed a graph so its metric matches a target shape::

        def objective(params):
            z = model.apply(params, node_feats)          # (n, d) embed
            return gw_loss(z, target_points, solver="dense_gw")
        grads = jax.grad(objective)(params)
    """
    problem = QuadraticProblem(_as_geometry(x, a), _as_geometry(y, b),
                               loss=loss, validate=False)
    return quadratic_loss(problem, solver, key,
                          marginal_grads=marginal_grads)


def fgw_loss(x, y, fx=None, fy=None, M=None, *, fused_penalty: Any = 0.5,
             a=None, b=None, loss: str = "l2",
             solver: Union[str, object, None] = None,
             key: Optional[jax.Array] = None,
             marginal_grads: bool = False):
    """Fused GW loss: ``α·⟨L⊗T, T⟩ + (1−α)·⟨M, T⟩``, differentiable in
    the structures (x, y), the features (fx, fy) / explicit ``M``, and
    α itself (``fused_penalty`` may be a traced scalar).

    Give either node features ``fx``/``fy`` (M becomes their pairwise
    squared distance — the learned-ground-cost hook: make fx the output
    of a model and differentiate through it) or an explicit ``M``.
    """
    if (fx is None) != (fy is None):
        raise ValueError("fgw_loss needs features on both sides or neither")
    if fx is None and M is None:
        raise ValueError(
            "fgw_loss needs a linear term: pass fx/fy features or M")
    problem = QuadraticProblem(_as_geometry(x, a, features=fx),
                               _as_geometry(y, b, features=fy),
                               loss=loss, fused_penalty=fused_penalty,
                               M=M, validate=False)
    return quadratic_loss(problem, solver, key,
                          marginal_grads=marginal_grads)
