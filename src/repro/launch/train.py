"""Production training launcher: sharded train loop + fault tolerance.

Features exercised here (and tested in tests/test_train_loop.py,
tests/test_elastic.py):
  · auto-resume from the latest valid checkpoint (bit-exact: data-pipeline
    state rides in the checkpoint)
  · async checkpointing every N steps, atomic publish, keep-k GC
  · straggler watchdog: per-step wall-time EMA, slow steps logged
  · elastic restore: checkpoints are sharding-agnostic; restoring onto a
    different mesh re-shards via device_put
  · XLA latency-hiding scheduler flags for compute/comm overlap (TPU)

Usage (CPU example run):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os
import time

# Compute/comm overlap: latency-hiding scheduler (effective on TPU; harmless
# on CPU). Must be set before jax initializes.
_LHS_FLAGS = ("--xla_tpu_enable_latency_hiding_scheduler=true "
              "--xla_tpu_megacore_fusion_allow_ags=true ")
if "dryrun" not in os.environ.get("REPRO_MODE", ""):
    os.environ.setdefault("XLA_FLAGS", "")
    if "latency_hiding" not in os.environ["XLA_FLAGS"] \
            and os.environ.get("REPRO_TPU"):
        os.environ["XLA_FLAGS"] += " " + _LHS_FLAGS

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import base as cb
from repro.data import TokenPipeline
from repro.distrib import sharding as shd
from repro.launch.steps import make_train_step
from repro.models.model_zoo import Model, set_activation_sharding
from repro.optim import adamw


class StragglerWatchdog:
    """Flags steps slower than factor x EMA (at pod scale: host attribution
    + preemption hooks; here: detection + logging, tested)."""

    def __init__(self, factor: float = 2.0, alpha: float = 0.2):
        self.factor = factor
        self.alpha = alpha
        self.ema = None
        self.events = []

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        if slow:
            self.events.append((step, dt, self.ema))
        self.ema = dt if self.ema is None else \
            (1 - self.alpha) * self.ema + self.alpha * dt
        return slow


def train(cfg, steps: int, global_batch: int, seq_len: int,
          ckpt_dir: str | None = None, ckpt_every: int = 20,
          mesh=None, act_dtype=jnp.float32, use_flash: bool = False,
          gw_align: bool = False, log_every: int = 10, keep: int = 3,
          schedule_total: int | None = None, base_lr: float = 3e-4):
    model = Model(cfg)
    pipe = TokenPipeline(cfg, seq_len, global_batch)
    total = schedule_total or steps
    step_fn = make_train_step(model, base_lr=base_lr, act_dtype=act_dtype,
                              remat=True, use_flash=use_flash,
                              gw_align=gw_align,
                              warmup=max(1, min(100, total // 10)),
                              total_steps=total)
    mgr = CheckpointManager(ckpt_dir, keep=keep) if ckpt_dir else None

    if mesh is not None:
        dp = shd.data_axes(mesh)
        set_activation_sharding(
            True, dp=dp,
            dp_size=int(np.prod([mesh.shape[a] for a in dp])),
            model_size=mesh.shape["model"])
        abstract = model.abstract_params()
        axes = model.param_axes()
        param_sh = shd.param_shardings(axes, abstract, mesh)
        opt_sh = adamw.AdamWState(shd.replicated(mesh), param_sh, param_sh)
        jit_step = jax.jit(step_fn, in_shardings=(param_sh, opt_sh, None),
                           out_shardings=(param_sh, opt_sh, None),
                           donate_argnums=(0, 1))
    else:
        param_sh = opt_sh = None
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    # ---- init or resume ----------------------------------------------------
    start = 0
    params = opt_state = None
    if mgr is not None and mgr.latest_step() is not None:
        start = mgr.latest_step()
        target = {"params": model.abstract_params(),
                  "opt": adamw.abstract_state(model.abstract_params())}
        target = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype), target)
        sh = {"params": param_sh, "opt": opt_sh} if param_sh else None
        restored, extra = mgr.restore(start, target, sh)
        params, opt_state = restored["params"], restored["opt"]
        pipe.load_state_dict(extra["pipeline"])
        print(f"[resume] from step {start}")
    if params is None:
        params = model.init(jax.random.PRNGKey(0))
        if mesh is not None:
            params = jax.device_put(params, param_sh)
        opt_state = adamw.init(params)

    watchdog = StragglerWatchdog()
    history = []
    for step in range(start, steps):
        batch = jax.tree.map(jnp.asarray, pipe.global_batch_at(step))
        t0 = time.time()
        params, opt_state, metrics = jit_step(params, opt_state, batch)
        metrics = jax.tree.map(float, jax.device_get(metrics))
        dt = time.time() - t0
        if watchdog.observe(step, dt):
            print(f"[straggler] step {step}: {dt:.2f}s vs ema "
                  f"{watchdog.ema:.2f}s")
        history.append(metrics)
        if log_every and step % log_every == 0:
            print(f"step {step:5d} loss {metrics['loss']:.4f} "
                  f"ce {metrics['ce']:.4f} gnorm {metrics['gnorm']:.2f} "
                  f"{dt*1e3:.0f}ms")
        pipe.step = step + 1
        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state},
                     extra={"pipeline": pipe.state_dict()}, blocking=False)
    if mgr is not None:
        mgr.wait()
        mgr.save(steps, {"params": params, "opt": opt_state},
                 extra={"pipeline": pipe.state_dict()})
    return params, opt_state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--gw-align", action="store_true",
                    help="enable the SPAR-GW representation alignment loss")
    ap.add_argument("--use-flash", action="store_true")
    args = ap.parse_args()
    cfg = cb.get_reduced(args.arch) if args.reduced else cb.get_arch(args.arch)
    train(cfg, args.steps, args.batch, args.seq, ckpt_dir=args.ckpt_dir,
          ckpt_every=args.ckpt_every, gw_align=args.gw_align,
          use_flash=args.use_flash)


if __name__ == "__main__":
    main()
