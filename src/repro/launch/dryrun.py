import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: SPMD
partitioning must succeed, memory_analysis must fit, and the compiled HLO
yields the roofline terms (FLOPs / bytes / collective bytes) recorded to
``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as cb
from repro.distrib import sharding as shd
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models.attention import set_flash_chunk
from repro.models.model_zoo import Model, set_activation_sharding
from repro.optim import adamw

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_RE2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _tensor_bytes(dtype: str, dims: str) -> int:
    nelem = 1
    if dims:
        for d in dims.split(","):
            nelem *= int(d)
    return nelem * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str):
    """Sum output bytes + ring-model wire bytes per collective op kind."""
    out = {k: {"count": 0, "out_bytes": 0, "wire_bytes": 0.0}
           for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for op in _COLL_OPS:
            token = f" {op}("
            alt = f" {op}-start("
            pos = stripped.find(token)
            if pos < 0:
                pos = stripped.find(alt)
            if pos < 0 or " = " not in stripped[:pos + 4]:
                continue
            lhs = stripped.split(f"{op}(")[0].split(f"{op}-start(")[0]
            sizes = [_tensor_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs)]
            ob = sum(sizes)
            m = _GROUP_RE.search(stripped)
            if m:
                g = len(m.group(1).split(","))
            else:
                m2 = _GROUP_RE2.search(stripped)
                g = int(m2.group(2)) if m2 else 2
            if g <= 1:
                continue            # degenerate single-device group: no wire
            if op == "all-gather":
                wire = ob * (g - 1) / g
            elif op == "all-reduce":
                wire = ob * 2 * (g - 1) / g
            elif op == "reduce-scatter":
                wire = ob * (g - 1)
            elif op == "all-to-all":
                wire = ob * (g - 1) / g
            else:  # collective-permute
                wire = ob
            out[op]["count"] += 1
            out[op]["out_bytes"] += ob
            out[op]["wire_bytes"] += wire
            break
    return out


def _metrics_shardings(mesh):
    rep = shd.replicated(mesh)
    return {"loss": rep, "ce": rep, "aux": rep, "gnorm": rep, "lr": rep}


def _batch_shardings(mesh, batch_specs, global_batch, seq_len):
    out = {}
    for k, v in batch_specs.items():
        if k == "cache":
            out[k] = jax.tree.map(
                lambda s: _cache_sharding(mesh, s.shape, global_batch, seq_len),
                v)
        elif k == "index":
            out[k] = shd.replicated(mesh)
        else:
            out[k] = shd.batch_sharding(mesh, len(v.shape), global_batch)
    return out


def _cache_sharding(mesh, shape, batch, seq_len):
    """Caches: stacked (L, B, S, ...) or unstacked (B, S, ...) or states
    (L, B, ...). Batch -> data axes; seq dim -> 'model' (plus data axes when
    batch is unshardable, e.g. the long-context B=1 cells)."""
    dp = shd.data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    model_size = mesh.shape["model"]
    spec = [None] * len(shape)
    # locate batch dim: index 1 if stacked else 0
    bdim = None
    for cand in (1, 0):
        if len(shape) > cand and shape[cand] == batch:
            bdim = cand
            break
    if bdim is not None and batch % dp_size == 0 and batch > 1:
        spec[bdim] = dp
        sdim = bdim + 1
        if len(shape) > sdim and shape[sdim] == seq_len \
                and seq_len % model_size == 0:
            spec[sdim] = "model"
    elif bdim is not None:
        sdim = bdim + 1
        if len(shape) > sdim and shape[sdim] == seq_len:
            if seq_len % (dp_size * model_size) == 0:
                spec[sdim] = tuple(dp) + ("model",)
            elif seq_len % model_size == 0:
                spec[sdim] = "model"
    return NamedSharding(mesh, P(*spec))


def _build_fn(cfg, shape, mesh, use_flash, rules, unroll: bool = False):
    """Construct the jitted step fn + abstract args for one cell."""
    model = Model(cfg, unroll_layers=unroll)
    abstract = model.abstract_params()
    axes = model.param_axes()
    param_sh = shd.param_shardings(axes, abstract, mesh, rules)
    batch_specs = sp.input_specs(cfg, shape)
    batch_sh = _batch_shardings(mesh, batch_specs, shape.global_batch,
                                shape.seq_len)
    if shape.kind == "train":
        opt_abs = adamw.abstract_state(abstract)
        opt_sh = adamw.AdamWState((shd.replicated(mesh)), param_sh, param_sh)
        step = make_train_step(model, use_flash=use_flash)
        fn = jax.jit(step,
                     in_shardings=(param_sh, opt_sh, batch_sh),
                     out_shardings=(param_sh, opt_sh, _metrics_shardings(mesh)))
        args = (abstract, opt_abs, batch_specs)
    elif shape.kind == "prefill":
        step = make_prefill_step(model, use_flash=use_flash)
        fn = jax.jit(step, in_shardings=(param_sh, batch_sh))
        args = (abstract, batch_specs)
    else:
        step = make_decode_step(model)
        fn = jax.jit(step, in_shardings=(param_sh, batch_sh))
        args = (abstract, batch_specs)
    return fn, args, abstract


def _slstm_correction_flops(cfg, shape):
    """Per-device FLOPs missed because sLSTM's seq scan is counted once by
    cost_analysis: (S-1) extra steps x 4 recurrent per-head matmuls."""
    n_slstm = (list(cfg.block_pattern).count("slstm")
               * cfg.resolved_superblocks
               + list(cfg.tail_blocks).count("slstm"))
    if n_slstm == 0:
        return 0.0
    pd = int(cfg.lstm_proj_factor * cfg.d_model)
    hd = pd // cfg.n_heads
    S = shape.seq_len if shape.kind in ("train", "prefill") else 1
    per_step = 2 * 4 * pd * hd * shape.global_batch
    fwd = n_slstm * (S - 1) * per_step
    mult = 3.0 if shape.kind == "train" else 1.0   # fwd+bwd
    return fwd * mult


def cost_extrapolate(cfg, shape, mesh, use_flash, rules,
                     flash_chunk: int = 1 << 30):
    """cost_analysis counts scan bodies once -> compile L=1 and L=2
    *unrolled* superblock variants and extrapolate flops/bytes linearly in
    the superblock count.

    flash_chunk = huge  -> single attention chunk: exact FLOP count, but
                           bytes include the S^2 score materialization the
                           production flash path avoids (upper bound).
    flash_chunk = 512   -> production blockwise program: bytes approximate
                           fused/VMEM-resident HBM traffic (chunk transients
                           counted once — the on-chip ideal); attention
                           FLOPs undercounted (use the other variant).
    """
    set_flash_chunk(flash_chunk)
    vals = {}
    for L in (1, 2):
        cfg_l = dataclasses.replace(cfg, n_superblocks=L)
        fn, args, _ = _build_fn(cfg_l, shape, mesh, use_flash, rules,
                                unroll=True)
        with mesh:
            c = fn.lower(*args).compile()
        ca = c.cost_analysis()
        vals[L] = (ca.get("flops", 0.0), ca.get("bytes accessed", 0.0))
    set_flash_chunk(512)
    n_sb = cfg.resolved_superblocks
    flops = vals[1][0] + (n_sb - 1) * (vals[2][0] - vals[1][0])
    byts = vals[1][1] + (n_sb - 1) * (vals[2][1] - vals[1][1])
    chips = int(np.prod(list(mesh.shape.values())))
    flops += _slstm_correction_flops(cfg, shape) / chips
    return flops, byts, {str(k): v for k, v in vals.items()}


def _add_cost_fields(rec, cfg, shape, mesh, use_flash, rules):
    """Scan-aware FLOP/byte accounting (two unrolled variants)."""
    flops, byts, pts = cost_extrapolate(cfg, shape, mesh, use_flash, rules)
    rec["flops_per_device"] = flops
    rec["bytes_unblocked_per_device"] = byts
    rec["cost_points"] = pts
    if shape.kind != "decode":
        _, byts_f, pts_f = cost_extrapolate(cfg, shape, mesh, use_flash,
                                            rules, flash_chunk=512)
        rec["bytes_per_device"] = byts_f
        rec["cost_points_flash"] = pts_f
    else:
        rec["bytes_per_device"] = byts
    return rec


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             use_flash: bool = True, rules=None, tag: str = "",
             sp: bool = False, with_cost: bool = True, cfg_overrides=None):
    cfg = cb.get_arch(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = cb.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    dp = shd.data_axes(mesh)
    set_activation_sharding(
        True, dp=dp,
        dp_size=int(np.prod([mesh.shape[a] for a in dp])),
        model_size=mesh.shape["model"], sp=sp)
    model = Model(cfg)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "mesh_shape": dict(mesh.shape), "kind": shape.kind, "tag": tag}
    t0 = time.time()

    fn, args, abstract = _build_fn(cfg, shape, mesh, use_flash, rules)
    rec["n_params"] = sum(int(np.prod(s.shape))
                          for s in jax.tree.leaves(abstract))

    with mesh:
        lowered = fn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    rec["lower_s"] = round(t1 - t0, 2)
    rec["compile_s"] = round(t2 - t1, 2)
    rec["memory"] = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "code_bytes": mem.generated_code_size_in_bytes,
    }
    rec["flops_raw"] = cost.get("flops", 0.0)
    rec["bytes_raw"] = cost.get("bytes accessed", 0.0)
    hlo = compiled.as_text()
    rec["collectives"] = parse_collectives(hlo)
    rec["hlo_lines"] = hlo.count("\n")
    del compiled, lowered, hlo

    if with_cost:
        _add_cost_fields(rec, cfg, shape, mesh, use_flash, rules)
    else:
        rec["flops_per_device"] = rec["flops_raw"]
        rec["bytes_per_device"] = rec["bytes_raw"]
    out_dir.mkdir(parents=True, exist_ok=True)
    fname = f"{arch.replace('/','_')}__{shape_name}__{mesh_kind}"
    if tag:
        fname += f"__{tag}"
    with open(out_dir / (fname + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[ok] {arch} {shape_name} {mesh_kind}{' ' + tag if tag else ''}: "
          f"lower {rec['lower_s']}s compile {rec['compile_s']}s "
          f"flops/dev {rec['flops_per_device']:.3g} "
          f"temp {mem.temp_size_in_bytes/2**30:.2f}GiB "
          f"args {mem.argument_size_in_bytes/2**30:.2f}GiB")
    return rec


def run_gw_cell(mesh_kind: str, out_dir: Path, s_r: int = 8192,
                s_c: int = 8192, outer: int = 10, inner: int = 30,
                tag: str = "", comm_dtype=None, submesh=None):
    """Dry-run the paper's own technique at pod scale: sharded Grid-SPAR-GW
    (s_r x s_c grid block over the full mesh; s = s_r*s_c samples — the
    n ≈ 4M-point regime at the paper's s = 16n).

    ``submesh=(d, m)`` runs the problem on a d×m submesh instead of the
    whole pod (production pattern: many independent GW problems, one per
    submesh — e.g. pairwise graph-distance workloads, paper §6.2 — rather
    than over-sharding a single small problem across 256 chips)."""
    import jax.numpy as jnp
    from repro.core.sharded_gw import make_sharded_grid_gw

    if submesh is not None:
        mesh = jax.make_mesh(submesh, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        if "pod" in mesh.axis_names:
            # fold the pod axis into data (pure row sharding)
            mesh = jax.make_mesh((32, 16), ("data", "model"))
    solver = make_sharded_grid_gw(mesh, s_r, s_c, "l2", 1e-2, outer, inner,
                                  comm_dtype=comm_dtype)
    f32 = jnp.float32
    args = (jax.ShapeDtypeStruct((s_r, s_r), f32),
            jax.ShapeDtypeStruct((s_c, s_c), f32),
            jax.ShapeDtypeStruct((s_r,), f32),
            jax.ShapeDtypeStruct((s_c,), f32),
            jax.ShapeDtypeStruct((s_r, s_c), f32))
    t0 = time.time()
    with mesh:
        lowered = solver.lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # shard_map body contains no scans over layers; fori over iters is
    # counted once -> multiply by outer*inner analytically for the sinkhorn
    # matvec part and outer for cost assembly: conservative (report both).
    rec = {"arch": "spargw-engine", "shape": f"grid{s_r}x{s_c}",
           "mesh": mesh_kind, "mesh_shape": dict(mesh.shape),
           "kind": "gw", "tag": tag, "n_params": 0,
           "lower_s": 0.0, "compile_s": round(time.time() - t0, 2),
           "memory": {
               "argument_bytes": mem.argument_size_in_bytes,
               "output_bytes": mem.output_size_in_bytes,
               "temp_bytes": mem.temp_size_in_bytes,
               "alias_bytes": mem.alias_size_in_bytes,
               "code_bytes": mem.generated_code_size_in_bytes},
           "flops_raw": cost.get("flops", 0.0),
           "bytes_raw": cost.get("bytes accessed", 0.0),
           # loop bodies counted once: one outer iter contains the cost
           # assembly + `inner`-counted-once sinkhorn. Scale by outer; add
           # (inner-1) matvec pairs analytically: 2*2*s_r*s_c flops each.
           "flops_per_device": (cost.get("flops", 0.0)
                                + (inner - 1) * 4.0 * s_r * s_c
                                / (mesh.shape["data"] * mesh.shape["model"])
                                ) * outer,
           "bytes_per_device": cost.get("bytes accessed", 0.0) * outer,
           "collectives": parse_collectives(hlo),
           "hlo_lines": hlo.count("\n")}
    # wire bytes also scale with the outer loop (counted once in HLO)
    for v in rec["collectives"].values():
        v["wire_bytes"] *= outer * (1 + inner / 4)   # sinkhorn psum pairs
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"spargw-engine__grid{s_r}x{s_c}__{mesh_kind}"
    if tag:
        name += f"__{tag}"
    with open(out_dir / (name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[ok] spargw-engine grid{s_r}x{s_c} {mesh_kind}: compile "
          f"{rec['compile_s']}s flops/dev {rec['flops_per_device']:.3g} "
          f"temp {mem.temp_size_in_bytes/2**30:.2f}GiB")
    return rec


def recost_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
                use_flash: bool = True, rules=None):
    """Recompute the scan-aware flop/byte extrapolation for an existing
    cell JSON (production compile results are reused untouched)."""
    fname = out_dir / f"{arch}__{shape_name}__{mesh_kind}.json"
    with open(fname) as f:
        rec = json.load(f)
    cfg = cb.get_arch(arch)
    shape = cb.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    dp = shd.data_axes(mesh)
    set_activation_sharding(
        True, dp=dp, dp_size=int(np.prod([mesh.shape[a] for a in dp])),
        model_size=mesh.shape["model"])
    t0 = time.time()
    _add_cost_fields(rec, cfg, shape, mesh, use_flash, rules)
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[recost] {arch} {shape_name} {mesh_kind}: "
          f"flops/dev {rec['flops_per_device']:.3g} "
          f"bytes/dev {rec['bytes_per_device']:.3g} "
          f"(unblocked {rec['bytes_unblocked_per_device']:.3g}) "
          f"({time.time()-t0:.0f}s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", type=str, default="single",
                    choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--cost-only", action="store_true")
    ap.add_argument("--gw", action="store_true",
                    help="dry-run the sharded GW engine instead of LM cells")
    ap.add_argument("--out", type=str, default=str(ART))
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.gw:
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        for mk in meshes:
            run_gw_cell(mk, out_dir)
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        archs = [a for a in cb.CLI_ALIASES]
    else:
        archs = [args.arch]

    failures = []
    for arch in archs:
        cfg = cb.get_arch(arch)
        shapes = [s.name for s in cb.shapes_for(cfg)] \
            if args.shape is None else [args.shape]
        for shape_name in shapes:
            for mesh_kind in meshes:
                fname = out_dir / (f"{arch}__{shape_name}__{mesh_kind}.json")
                if args.cost_only:
                    try:
                        recost_cell(arch, shape_name, mesh_kind, out_dir)
                    except Exception as e:  # noqa: BLE001
                        traceback.print_exc()
                        failures.append((arch, shape_name, mesh_kind,
                                         str(e)[:200]))
                    continue
                if args.skip_existing and fname.exists():
                    print(f"[skip] {arch} {shape_name} {mesh_kind}")
                    continue
                try:
                    run_cell(arch, shape_name, mesh_kind, out_dir)
                except Exception as e:  # noqa: BLE001 — record and continue
                    traceback.print_exc()
                    failures.append((arch, shape_name, mesh_kind, str(e)[:200]))
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("all cells passed")


if __name__ == "__main__":
    main()
