"""jit-able step functions shared by the trainer, server, and dry-run."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model_zoo import Model
from repro.optim import adamw


def make_train_step(model: Model, base_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10000, act_dtype=jnp.bfloat16,
                    remat: bool = True, use_flash: bool = False,
                    gw_align: bool = False):
    lr_fn = adamw.cosine_schedule(base_lr, warmup, total_steps)

    def train_step(params, opt_state, batch):
        gw_key = jax.random.fold_in(jax.random.PRNGKey(17), opt_state.step)

        def loss_fn(p):
            return model.loss(p, batch, act_dtype=act_dtype, remat=remat,
                              use_flash=use_flash, gw_align=gw_align,
                              gw_key=gw_key)

        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        lr = lr_fn(opt_state.step + 1)      # step counter increments in update
        new_params, new_state, gnorm = adamw.update(grads, opt_state, params, lr)
        metrics = {"loss": loss, "ce": parts["ce"], "aux": parts["aux"],
                   "gnorm": gnorm, "lr": lr}
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(model: Model, act_dtype=jnp.bfloat16,
                      use_flash: bool = False):
    def prefill_step(params, batch):
        return model.prefill(params, batch["tokens"],
                             img=batch.get("image_embeds"),
                             act_dtype=act_dtype, use_flash=use_flash)
    return prefill_step


def make_decode_step(model: Model, act_dtype=jnp.bfloat16):
    def decode_step(params, batch):
        return model.decode_step(params, batch["tokens"], batch["cache"],
                                 batch["index"],
                                 img=batch.get("image_embeds"),
                                 act_dtype=act_dtype)
    return decode_step
