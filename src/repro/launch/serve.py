"""Serving entry points.

``--mode gw`` (default) launches the GW solve server
(:mod:`repro.serve`): a synthetic catalog-matching workload is driven
through :class:`~repro.serve.GWServer` — size-bucketed batching, the
content-hash geometry cache, per-request health status — and the
server's metrics summary is printed. This is the CLI face of the
serving layer (DESIGN.md §9); ``benchmarks/bench_serve.py`` is its
measurement-grade sibling.

``--mode lm`` keeps the original LM serving loop: batched prefill +
decode with a KV/state cache, plus a GW-distance scoring mode (the
paper's technique as a serving feature — structural similarity between
the hidden geometries of request batches). ``generate`` and
``gw_similarity`` remain importable from here (tests/test_system.py,
examples/serve_lm_demo.py).

Usage (CPU examples):
  PYTHONPATH=src python -m repro.launch.serve --requests 16 --max-batch 8
  PYTHONPATH=src python -m repro.launch.serve --mode lm --arch smollm-135m \
      --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cb
from repro.core.align import gw_alignment_loss
from repro.models.model_zoo import Model


# ---------------------------------------------------------------------------
# GW solve-server mode
# ---------------------------------------------------------------------------

def _demo_geometry(n: int, seed: int):
    import repro
    rng = np.random.default_rng(seed)
    pts = rng.standard_normal((n, 2)).astype(np.float32)
    C = np.sqrt(((pts[:, None] - pts[None]) ** 2).sum(-1)).astype(np.float32)
    return repro.Geometry(jnp.asarray(C),
                          jnp.full(n, 1.0 / n, jnp.float32))


def gw_main(args) -> None:
    """Drive a synthetic catalog workload through GWServer and print the
    per-request outcomes + the metrics summary."""
    import repro
    from repro.serve import GWServer, ServeConfig

    http_server = None
    if getattr(args, "metrics_port", 0):
        from repro.obs import serve_metrics_http
        http_server = serve_metrics_http(args.metrics_port)
        host, port = http_server.server_address[:2]
        print(f"metrics: http://{host}:{port}/metrics "
              f"(Prometheus text format)")

    server = GWServer(ServeConfig(max_batch=args.max_batch,
                                  max_wait_s=args.max_wait,
                                  on_failure=args.on_failure))
    solver = repro.get_solver(args.solver).default_config(64)
    needs_key = getattr(type(solver), "requires_key", False)

    reference = _demo_geometry(32, seed=999)
    sizes = (12, 18, 24, 28)
    t0 = time.time()
    rids = []
    for i in range(args.requests):
        query = _demo_geometry(sizes[i % len(sizes)], seed=100 + i % 6)
        problem = repro.QuadraticProblem(query, reference)
        key = jax.random.PRNGKey(i) if needs_key else None
        rids.append(server.submit(problem, solver, key=key))
    results = server.results(rids)
    dt = time.time() - t0

    for r in results:
        print(f"  rid={r.rid:3d} shape={r.shape} -> bucket{r.padded_shape} "
              f"value={r.value:.5f} status={r.status_name}"
              f"{' (fallback)' if r.fell_back else ''} "
              f"latency={r.latency_s * 1e3:.1f}ms")
    print(f"served {len(results)} requests in {dt:.2f}s "
          f"({len(results) / dt:.1f} req/s)")
    stats = server.stats()
    for k in sorted(stats):
        v = stats[k]
        print(f"  {k} = {v:.4f}" if isinstance(v, float) else
              f"  {k} = {v}")


# ---------------------------------------------------------------------------
# LM serving mode (legacy entry, kept importable)
# ---------------------------------------------------------------------------

def generate(model: Model, params, prompts, max_new: int,
             act_dtype=jnp.float32, temperature: float = 0.0, img=None,
             rng=None):
    """prompts: (B, S0) int32. Greedy (or sampled) continuation.

    Decode runs against a cache of length S0 + max_new; prefill fills the
    first S0 entries (written into the padded cache functionally).
    """
    B, S0 = prompts.shape[0], prompts.shape[1]
    total = S0 + max_new
    cache = model.init_cache(B, total, dtype=act_dtype)

    decode = jax.jit(
        lambda p, tok, c, idx: model.decode_step(p, tok, c, idx, img=img,
                                                 act_dtype=act_dtype))

    # teacher-forced prefill via decode steps on the padded cache (exact);
    # a fused prefill kernel is the production path for long prompts.
    tok = prompts[:, :1] if prompts.ndim == 2 else prompts[:, :1, :]
    logits = None
    for t in range(S0):
        logits, cache = decode(params, prompts[:, t:t + 1], cache,
                               jnp.int32(t))
    out = [prompts]
    rng = rng or jax.random.PRNGKey(0)
    for t in range(S0, total):
        if temperature > 0:
            rng, k = jax.random.split(rng)
            nxt = jax.random.categorical(k, logits[:, -1] / temperature,
                                         axis=-1)[:, None]
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(nxt.astype(jnp.int32))
        logits, cache = decode(params, nxt.astype(jnp.int32), cache,
                               jnp.int32(t))
    return jnp.concatenate(out, axis=1)


def gw_similarity(model: Model, params, batch_a, batch_b, s: int = 32,
                  act_dtype=jnp.float32):
    """GW distance between the hidden geometries of two request batches."""
    _, h_a, _ = model.forward(params, batch_a, act_dtype=act_dtype)
    _, h_b, _ = model.forward(params, batch_b, act_dtype=act_dtype)
    return gw_alignment_loss(jax.random.PRNGKey(0), h_a, h_b, s_r=s, s_c=s)


def lm_main(args) -> None:
    cfg = cb.get_reduced(args.arch) if args.reduced else cb.get_arch(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(7)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    seqs = generate(model, params, prompts, args.gen)
    dt = time.time() - t0
    print(f"generated {seqs.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    if args.metric == "gw":
        sim = gw_similarity(model, params, prompts,
                            jnp.flip(prompts, axis=0))
        print(f"GW(batch, reversed-batch) = {float(sim):.5f}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=("gw", "lm"), default="gw",
                    help="gw: GW solve server demo (default); lm: batched "
                         "LM generation loop")
    gw = ap.add_argument_group("gw mode")
    gw.add_argument("--requests", type=int, default=16)
    gw.add_argument("--solver", default="dense_gw")
    gw.add_argument("--max-batch", type=int, default=8)
    gw.add_argument("--max-wait", type=float, default=0.02)
    gw.add_argument("--on-failure", choices=("none", "fallback"),
                    default="fallback")
    gw.add_argument("--metrics-port", type=int, default=0,
                    help="serve the process metrics registry as Prometheus "
                         "text on this port (0 = off)")
    lm = ap.add_argument_group("lm mode")
    lm.add_argument("--arch", default=None)
    lm.add_argument("--reduced", action="store_true")
    lm.add_argument("--batch", type=int, default=4)
    lm.add_argument("--prompt-len", type=int, default=32)
    lm.add_argument("--gen", type=int, default=16)
    lm.add_argument("--metric", choices=("none", "gw"), default="none")
    args = ap.parse_args()
    if args.mode == "lm":
        if args.arch is None:
            ap.error("--mode lm requires --arch")
        lm_main(args)
    else:
        gw_main(args)


if __name__ == "__main__":
    main()
