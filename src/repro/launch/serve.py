"""Batched serving loop: prefill + decode with a KV/state cache, plus a
GW-distance scoring mode (the paper's technique as a serving feature —
structural similarity between the hidden geometries of request batches).

Usage (CPU example):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cb
from repro.core.align import gw_alignment_loss
from repro.models.model_zoo import Model


def generate(model: Model, params, prompts, max_new: int,
             act_dtype=jnp.float32, temperature: float = 0.0, img=None,
             rng=None):
    """prompts: (B, S0) int32. Greedy (or sampled) continuation.

    Decode runs against a cache of length S0 + max_new; prefill fills the
    first S0 entries (written into the padded cache functionally).
    """
    B, S0 = prompts.shape[0], prompts.shape[1]
    total = S0 + max_new
    cache = model.init_cache(B, total, dtype=act_dtype)

    decode = jax.jit(
        lambda p, tok, c, idx: model.decode_step(p, tok, c, idx, img=img,
                                                 act_dtype=act_dtype))

    # teacher-forced prefill via decode steps on the padded cache (exact);
    # a fused prefill kernel is the production path for long prompts.
    tok = prompts[:, :1] if prompts.ndim == 2 else prompts[:, :1, :]
    logits = None
    for t in range(S0):
        logits, cache = decode(params, prompts[:, t:t + 1], cache,
                               jnp.int32(t))
    out = [prompts]
    rng = rng or jax.random.PRNGKey(0)
    for t in range(S0, total):
        if temperature > 0:
            rng, k = jax.random.split(rng)
            nxt = jax.random.categorical(k, logits[:, -1] / temperature,
                                         axis=-1)[:, None]
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(nxt.astype(jnp.int32))
        logits, cache = decode(params, nxt.astype(jnp.int32), cache,
                               jnp.int32(t))
    return jnp.concatenate(out, axis=1)


def gw_similarity(model: Model, params, batch_a, batch_b, s: int = 32,
                  act_dtype=jnp.float32):
    """GW distance between the hidden geometries of two request batches."""
    _, h_a, _ = model.forward(params, batch_a, act_dtype=act_dtype)
    _, h_b, _ = model.forward(params, batch_b, act_dtype=act_dtype)
    return gw_alignment_loss(jax.random.PRNGKey(0), h_a, h_b, s_r=s, s_c=s)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--metric", choices=("none", "gw"), default="none")
    args = ap.parse_args()
    cfg = cb.get_reduced(args.arch) if args.reduced else cb.get_arch(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(7)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    seqs = generate(model, params, prompts, args.gen)
    dt = time.time() - t0
    print(f"generated {seqs.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    if args.metric == "gw":
        sim = gw_similarity(model, params, prompts,
                            jnp.flip(prompts, axis=0))
        print(f"GW(batch, reversed-batch) = {float(sim):.5f}")


if __name__ == "__main__":
    main()
