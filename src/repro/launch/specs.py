"""ShapeDtypeStruct input stand-ins per (arch × shape) cell — no allocation.

``input_specs`` mirrors the real batch structure from the data pipeline /
serving frontends: weak-type-correct, shardable. Modality frontends are
stubs per the assignment: VLM cells get precomputed patch embeddings, audio
cells get multi-codebook token grids.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.model_zoo import Model


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                cache_dtype=jnp.bfloat16) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    model = Model(cfg)
    if shape.kind == "train":
        tok_shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
        specs = {"tokens": sds(tok_shape, jnp.int32),
                 "labels": sds(tok_shape, jnp.int32)}
        if cfg.family == "vlm":
            specs["image_embeds"] = sds((B, cfg.n_image_tokens, cfg.d_model),
                                        jnp.bfloat16)
        return specs
    if shape.kind == "prefill":
        tok_shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
        specs = {"tokens": sds(tok_shape, jnp.int32)}
        if cfg.family == "vlm":
            specs["image_embeds"] = sds((B, cfg.n_image_tokens, cfg.d_model),
                                        jnp.bfloat16)
        return specs
    # decode: one new token, cache of length S
    tok_shape = (B, 1, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, 1)
    specs = {"tokens": sds(tok_shape, jnp.int32),
             "index": sds((), jnp.int32),
             "cache": model.cache_spec(B, S, cache_dtype)}
    if cfg.family == "vlm":
        specs["image_embeds"] = sds((B, cfg.n_image_tokens, cfg.d_model),
                                    jnp.bfloat16)
    return specs
