"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (subprocess with forced host
    device count)."""
    return jax.make_mesh(shape, axes)
