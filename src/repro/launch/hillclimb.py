import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimbing driver (EXPERIMENTS.md §Perf).

Runs a named optimization variant for one (arch × shape × mesh) cell,
records a tagged artifact JSON, and prints the before/after roofline terms.

Variants:
  sp          — sequence-parallel residual stream (saved activations under
                remat shard over 'model'; SP all-gather at layer entry)
  moe_bf16    — bf16 MoE dispatch/combine tensors
  sp+moe_bf16 — both
  embed_repl  — replicate the token embedding over 'model' (kills the
                vocab-TP gather collective at the cost of replicated table)

Usage:
  python -m repro.launch.hillclimb --arch llama-3.2-vision-90b \
      --shape train_4k --mesh single --variant sp
"""
import argparse
import json
from pathlib import Path

import numpy as np

from repro.distrib import sharding as shd
from repro.launch import dryrun as dr
from repro.models.moe import set_moe_options
from repro.models.sharding_ctx import set_activation_sharding
from repro.models.ssm import set_mamba_options


def apply_variant(variant: str, mesh):
    rules = None
    parts = variant.split("+")
    dp = shd.data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    set_activation_sharding(True, dp=dp, dp_size=dp_size,
                            model_size=mesh.shape["model"], sp="sp" in parts)
    set_moe_options(bf16_dispatch="moe_bf16" in parts)
    set_mamba_options(split_proj="mamba_split" in parts)
    if "fc256" in parts:
        from repro.models.attention import set_flash_chunk
        set_flash_chunk(256)
    if "embed_repl" in parts:
        rules = dict(shd.DEFAULT_RULES)
        rules["vocab"] = (None,)
    return rules


def run_variant(arch, shape, mesh_kind, variant, out_dir):
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = apply_variant(variant, mesh)
    overrides = {}
    for p in variant.split("+"):
        if p.startswith("ssmchunk"):
            overrides["ssm_chunk"] = int(p[len("ssmchunk"):])
    return dr.run_cell(arch, shape, mesh_kind, out_dir, rules=rules,
                       tag=variant, sp=("sp" in variant.split("+")),
                       cfg_overrides=overrides or None)


def compare(arch, shape, mesh_kind, variant, out_dir):
    import sys
    sys.path.insert(0, str(Path(__file__).resolve().parents[3]))
    from benchmarks.roofline import analyze
    base = json.load(open(out_dir / f"{arch}__{shape}__{mesh_kind}.json"))
    var = json.load(
        open(out_dir / f"{arch}__{shape}__{mesh_kind}__{variant}.json"))
    a, b = analyze(base), analyze(var)
    print(f"\n{arch} {shape} {mesh_kind} — baseline -> {variant}")
    for k in ("t_compute_s", "t_memory_s", "t_collective_s", "temp_GiB"):
        delta = (b[k] - a[k]) / a[k] * 100 if a[k] else 0.0
        print(f"  {k:16s} {a[k]:10.3e} -> {b[k]:10.3e}  ({delta:+.1f}%)")
    print(f"  dominant: {a['dominant']} -> {b['dominant']}; "
          f"roofline frac {a['roofline_fraction']:.3f} -> "
          f"{b['roofline_fraction']:.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", required=True)
    ap.add_argument("--out", default=str(dr.ART))
    args = ap.parse_args()
    out = Path(args.out)
    run_variant(args.arch, args.shape, args.mesh, args.variant, out)
    compare(args.arch, args.shape, args.mesh, args.variant, out)


if __name__ == "__main__":
    main()
