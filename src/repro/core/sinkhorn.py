"""Sinkhorn-scaling solvers: dense, log-domain, unbalanced, and sparse (COO).

All loops are ``lax``-native. Every solver has a plain-domain variant
(faithful to Alg. 1/2/3 as written) and a log-domain variant (production
default — small ε and proximal kernels underflow fp32 otherwise).
``differentiable=True`` variants use ``lax.scan`` so reverse-mode AD works
(used by the GW alignment loss).

Every solver takes ``tol`` (static): ``tol=0`` runs the paper's fixed
iteration budget via ``fori_loop`` (bitwise-identical to the historical
behavior); ``tol>0`` runs a bounded ``while_loop`` that stops once the
sup-norm change of the scaling potentials drops below ``tol``. The while
path masks finished lanes so it is safe under ``vmap`` (see
api/driver.py for the same trick on the outer loop); the
``differentiable=True`` variants require ``tol=0`` (reverse-mode AD
needs the fixed-length scan) and raise otherwise. An unconverged
marginal projection is not a harmless inexactness: it stalls the outer
PGA loop at a non-coupling fixed point (the two historical pga_gw test
failures), so production configs should set an inner tolerance.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.utils import safe_div

_NEG_INF = -1e30   # proxy for -inf that stays NaN-free under arithmetic


def _finite(x):
    return jnp.where(jnp.isfinite(x) & (x > _NEG_INF / 2), x, 0.0)


def _scaling_loop(body, init, iters: int, tol: float):
    """Run ``carry <- body(carry)`` for a fixed budget or to tolerance.

    ``body`` maps a tuple of potential vectors to the updated tuple.
    ``tol=0`` → ``fori_loop`` over the full budget (legacy numerics).
    ``tol>0`` → bounded ``while_loop``, stopping when the largest absolute
    change across all potentials is <= tol; finished lanes are frozen so
    the loop is vmap-safe.
    """
    if not tol or tol <= 0.0:
        return lax.fori_loop(0, iters, lambda _, c: body(c), init)

    def cond(state):
        i, _, done = state
        return (i < iters) & jnp.logical_not(done)

    def wl_body(state):
        i, carry, done = state
        new = body(carry)
        delta = jnp.max(jnp.stack(
            [jnp.max(jnp.abs(n - o)) for n, o in zip(new, carry)]))
        frozen = tuple(jnp.where(done, o, n) for n, o in zip(new, carry))
        return (jnp.where(done, i, i + 1), frozen, done | (delta <= tol))

    _, carry, _ = lax.while_loop(
        cond, wl_body, (jnp.int32(0), init, jnp.bool_(False)))
    return carry


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------

def sinkhorn(a, b, K, iters: int, differentiable: bool = False,
             tol: float = 0.0):
    """Plain Sinkhorn scaling (Alg. 1 step 5): u = a ⊘ (K v), v = b ⊘ (Kᵀ u)."""
    m, n = K.shape
    u0 = jnp.ones((m,), K.dtype)
    v0 = jnp.ones((n,), K.dtype)

    def body(carry):
        u, v = carry
        u = safe_div(a, K @ v)
        v = safe_div(b, K.T @ u)
        return (u, v)

    if differentiable and tol and tol > 0.0:
        raise ValueError(
            "tol-based early stopping is not supported with "
            "differentiable=True (reverse-mode AD needs the fixed-length "
            "scan); pass tol=0")
    if differentiable:
        (u, v), _ = lax.scan(lambda c, _: (body(c), None), (u0, v0), None,
                             length=iters)
    else:
        u, v = _scaling_loop(body, (u0, v0), iters, tol)
    return u[:, None] * K * v[None, :]


def sinkhorn_log(a, b, logK, iters: int, differentiable: bool = False,
                 tol: float = 0.0):
    """Log-domain Sinkhorn. Returns the coupling T (dense)."""
    m, n = logK.shape
    la = jnp.log(jnp.maximum(a, 1e-38))
    lb = jnp.log(jnp.maximum(b, 1e-38))
    f0 = jnp.zeros((m,), logK.dtype)
    g0 = jnp.zeros((n,), logK.dtype)

    def body(carry):
        f, g = carry
        f = _finite(la - jax.scipy.special.logsumexp(logK + g[None, :], axis=1))
        g = _finite(lb - jax.scipy.special.logsumexp(logK + f[:, None], axis=0))
        return (f, g)

    if differentiable and tol and tol > 0.0:
        raise ValueError(
            "tol-based early stopping is not supported with "
            "differentiable=True (reverse-mode AD needs the fixed-length "
            "scan); pass tol=0")
    if differentiable:
        (f, g), _ = lax.scan(lambda c, _: (body(c), None), (f0, g0), None,
                             length=iters)
    else:
        f, g = _scaling_loop(body, (f0, g0), iters, tol)
    return jnp.exp(logK + f[:, None] + g[None, :])


def sinkhorn_unbalanced(a, b, K, lam, eps, iters: int, tol: float = 0.0):
    """Plain unbalanced Sinkhorn (Alg. 3 step 9): exponent λ̄/(λ̄+ε̄)."""
    m, n = K.shape
    rho = lam / (lam + eps)
    u0 = jnp.ones((m,), K.dtype)
    v0 = jnp.ones((n,), K.dtype)

    def body(carry):
        u, v = carry
        u = safe_div(a, K @ v) ** rho
        v = safe_div(b, K.T @ u) ** rho
        return (u, v)

    u, v = _scaling_loop(body, (u0, v0), iters, tol)
    return u[:, None] * K * v[None, :]


def sinkhorn_unbalanced_log(a, b, logK, lam, eps, iters: int,
                            tol: float = 0.0):
    """Log-domain unbalanced Sinkhorn: log u = ρ (log a - lse(logK + log v))."""
    m, n = logK.shape
    rho = lam / (lam + eps)
    la = jnp.log(jnp.maximum(a, 1e-38))
    lb = jnp.log(jnp.maximum(b, 1e-38))
    f0 = jnp.zeros((m,), logK.dtype)
    g0 = jnp.zeros((n,), logK.dtype)

    def body(carry):
        f, g = carry
        f = _finite(rho * (la - jax.scipy.special.logsumexp(logK + g[None, :], axis=1)))
        g = _finite(rho * (lb - jax.scipy.special.logsumexp(logK + f[:, None], axis=0)))
        return (f, g)

    f, g = _scaling_loop(body, (f0, g0), iters, tol)
    return jnp.exp(logK + f[:, None] + g[None, :])


# ---------------------------------------------------------------------------
# Sparse (COO) — the paper's Step 7 with sparse matvecs, O(H s).
# ---------------------------------------------------------------------------

def coo_matvec(rows, cols, vals, x, out_dim: int):
    """y_i = Σ_{l: rows_l = i} vals_l * x[cols_l] — sparse K @ x."""
    return jax.ops.segment_sum(vals * x[cols], rows, num_segments=out_dim)


def segment_logsumexp(vals, segs, num: int):
    """Per-segment logsumexp; empty segments -> _NEG_INF. NaN-free."""
    maxs = jax.ops.segment_max(vals, segs, num_segments=num)
    maxs_safe = jnp.where(maxs > _NEG_INF / 2, maxs, 0.0)
    sums = jax.ops.segment_sum(jnp.exp(vals - maxs_safe[segs]), segs,
                               num_segments=num)
    out = jnp.log(jnp.maximum(sums, 1e-38)) + maxs_safe
    return jnp.where(sums > 0, out, _NEG_INF)


@partial(jax.jit, static_argnames=("m", "n", "iters", "tol"))
def sparse_sinkhorn(a, b, rows, cols, vals, m: int, n: int, iters: int,
                    tol: float = 0.0):
    """Plain-domain sparse Sinkhorn on a COO kernel (paper-faithful).

    Returns the COO values of the coupling T̃ (same sparsity pattern).
    Rows/cols without support get scaling 0 (dead), matching sparse
    implementations of Alg. 2.
    """
    u0 = jnp.ones((m,), vals.dtype)
    v0 = jnp.ones((n,), vals.dtype)

    def body(carry):
        u, v = carry
        u = safe_div(a, coo_matvec(rows, cols, vals, v, m))
        v = safe_div(b, coo_matvec(cols, rows, vals, u, n))
        return (u, v)

    u, v = _scaling_loop(body, (u0, v0), iters, tol)
    return u[rows] * vals * v[cols]


@partial(jax.jit, static_argnames=("m", "n", "iters", "tol"))
def sparse_sinkhorn_logdomain(a, b, rows, cols, logvals, m: int, n: int,
                              iters: int, tol: float = 0.0):
    """Log-domain sparse Sinkhorn (production default; small-ε safe)."""
    la = jnp.log(jnp.maximum(a, 1e-38))
    lb = jnp.log(jnp.maximum(b, 1e-38))
    f0 = jnp.zeros((m,), logvals.dtype)
    g0 = jnp.zeros((n,), logvals.dtype)

    def body(carry):
        f, g = carry
        f = _finite(la - segment_logsumexp(logvals + g[cols], rows, m))
        g = _finite(lb - segment_logsumexp(logvals + f[rows], cols, n))
        return (f, g)

    f, g = _scaling_loop(body, (f0, g0), iters, tol)
    return jnp.exp(logvals + f[rows] + g[cols])


@partial(jax.jit, static_argnames=("m", "n", "iters", "tol"))
def sparse_sinkhorn_unbalanced(a, b, rows, cols, vals, lam, eps,
                               m: int, n: int, iters: int, tol: float = 0.0):
    """Plain-domain unbalanced sparse Sinkhorn (Alg. 3 step 9)."""
    rho = lam / (lam + eps)
    u0 = jnp.ones((m,), vals.dtype)
    v0 = jnp.ones((n,), vals.dtype)

    def body(carry):
        u, v = carry
        u = safe_div(a, coo_matvec(rows, cols, vals, v, m)) ** rho
        v = safe_div(b, coo_matvec(cols, rows, vals, u, n)) ** rho
        return (u, v)

    u, v = _scaling_loop(body, (u0, v0), iters, tol)
    return u[rows] * vals * v[cols]


@partial(jax.jit, static_argnames=("m", "n", "iters", "tol"))
def sparse_sinkhorn_unbalanced_log(a, b, rows, cols, logvals, lam, eps,
                                   m: int, n: int, iters: int,
                                   tol: float = 0.0):
    """Log-domain unbalanced sparse Sinkhorn."""
    rho = lam / (lam + eps)
    la = jnp.log(jnp.maximum(a, 1e-38))
    lb = jnp.log(jnp.maximum(b, 1e-38))
    f0 = jnp.zeros((m,), logvals.dtype)
    g0 = jnp.zeros((n,), logvals.dtype)

    def body(carry):
        f, g = carry
        f = _finite(rho * (la - segment_logsumexp(logvals + g[cols], rows, m)))
        g = _finite(rho * (lb - segment_logsumexp(logvals + f[rows], cols, n)))
        return (f, g)

    f, g = _scaling_loop(body, (f0, g0), iters, tol)
    return jnp.exp(logvals + f[rows] + g[cols])
