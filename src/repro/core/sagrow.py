"""SaGroW baseline (Kerdoncuff et al., 2021) — sampled-gradient GW.

At each outer step the GW gradient M = L(Cx, Cy) ⊗ T is estimated from s'
index pairs sampled ∝ T (self-normalized importance sampling), followed by a
KL-proximal Sinkhorn step. O(s' m n) per iteration. This is the paper's main
sampling-based competitor (Table 1, Figs. 2-3).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ground_cost as gc
from repro.core.sinkhorn import sinkhorn


def _sampled_gradient(key, Cx, Cy, T, s_prime: int, loss: str,
                      chunk: int = 32):
    """M̂ = (1/s') Σ_l L(Cx[:, i_l], Cy[:, j_l]),  (i_l, j_l) ~ T/m(T)."""
    L = gc.get_loss(loss)
    m, n = T.shape
    probs = (T / jnp.sum(T)).reshape(-1)
    flat = jax.random.choice(key, m * n, (s_prime,), p=probs)
    ii, jj = flat // n, flat % n

    def body(c, acc):
        i_c = lax.dynamic_slice_in_dim(ii, c * chunk, chunk)
        j_c = lax.dynamic_slice_in_dim(jj, c * chunk, chunk)
        A = Cx[:, i_c]                      # (m, chunk)
        B = Cy[:, j_c]                      # (n, chunk)
        contrib = L(A[:, None, :], B[None, :, :]).sum(axis=-1)   # (m, n)
        return acc + contrib

    assert s_prime % chunk == 0 or s_prime < chunk
    chunk = min(chunk, s_prime)
    acc = lax.fori_loop(0, s_prime // chunk, body,
                        jnp.zeros((m, n), T.dtype))
    return acc / s_prime


@partial(jax.jit, static_argnames=("s_prime", "loss", "outer_iters",
                                   "inner_iters"))
def sagrow(key, a, b, Cx, Cy, s_prime: int, loss: str = "l2",
           epsilon: float = 1e-2, outer_iters: int = 20,
           inner_iters: int = 50):
    """Returns (gw_estimate_of_final_plan, T). Estimate uses one extra
    sampled-gradient evaluation: GW ≈ <M̂(T), T> (unbiased given T)."""
    T0 = a[:, None] * b[None, :]
    keys = jax.random.split(key, outer_iters + 1)

    def outer(T, k):
        M = _sampled_gradient(k, Cx, Cy, T, s_prime, loss)
        K = jnp.exp(-(M - jnp.min(M)) / epsilon) * T
        return sinkhorn(a, b, K, inner_iters), None

    T, _ = lax.scan(outer, T0, keys[:-1])
    M = _sampled_gradient(keys[-1], Cx, Cy, T, s_prime, loss)
    return jnp.sum(M * T), T
