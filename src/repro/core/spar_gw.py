"""SPAR-GW / SPAR-FGW — legacy entry points (deprecation shims).

The solver implementations live in the unified API layer
(``repro.api.solvers.SparGWSolver``, driven by the shared tolerance-aware
outer loop in ``repro.api.driver``); ``repro.solve`` is the front door.
These functions keep the original positional signatures and bare-tuple
returns for existing callers and return values bitwise-identical to the
corresponding ``repro.solve`` call (asserted in tests/test_api.py).
"""
from __future__ import annotations

import warnings


def _warn_deprecated(name: str):
    warnings.warn(
        f"repro.core.{name} is a deprecation shim; build a QuadraticProblem "
        f"and call repro.solve(...) instead (see DESIGN.md §'API layer')",
        DeprecationWarning, stacklevel=3)


def spar_cost(Cx, Cy, rows, cols, tvals, loss: str, chunk: int = 1024):
    """Reference COO cost assembly (kept as the public jnp oracle)."""
    from repro.kernels.spar_cost.ref import spar_cost_ref
    return spar_cost_ref(Cx, Cy, rows, cols, tvals, loss, chunk)


def spar_gw(key, a, b, Cx, Cy, s: int, loss: str = "l2", reg: str = "prox",
            epsilon: float = 1e-2, outer_iters: int = 20,
            inner_iters: int = 50, shrink: float = 0.0,
            cost_chunk: int = 1024, stable: bool = True,
            cost_impl: str = "auto"):
    """Algorithm 2 (shim). Returns (gw_estimate, (rows, cols, vals))."""
    from repro.api import Geometry, QuadraticProblem, SparGWSolver, solve
    _warn_deprecated("spar_gw")
    problem = QuadraticProblem(Geometry(Cx, a, validate=False),
                               Geometry(Cy, b, validate=False),
                               loss=loss, validate=False)
    solver = SparGWSolver(s=s, reg=reg, epsilon=epsilon,
                          outer_iters=outer_iters, inner_iters=inner_iters,
                          shrink=shrink, cost_chunk=cost_chunk,
                          stable=stable, cost_impl=cost_impl)
    out = solve(problem, solver, key=key, validate=False)
    c = out.coupling
    return out.value, (c.rows, c.cols, c.vals)


def spar_fgw(key, a, b, Cx, Cy, M, s: int, alpha: float = 0.6,
             loss: str = "l2", reg: str = "prox", epsilon: float = 1e-2,
             outer_iters: int = 20, inner_iters: int = 50,
             shrink: float = 0.0, cost_chunk: int = 1024,
             stable: bool = True, cost_impl: str = "auto"):
    """SPAR-FGW — Algorithm 4 (shim). Fused GW with feature matrix M.

    Returns (fgw_estimate, (rows, cols, coupling_values)).
    """
    from repro.api import Geometry, QuadraticProblem, SparGWSolver, solve
    _warn_deprecated("spar_fgw")
    problem = QuadraticProblem(Geometry(Cx, a, validate=False),
                               Geometry(Cy, b, validate=False),
                               loss=loss, fused_penalty=alpha, M=M,
                               validate=False)
    solver = SparGWSolver(s=s, reg=reg, epsilon=epsilon,
                          outer_iters=outer_iters, inner_iters=inner_iters,
                          shrink=shrink, cost_chunk=cost_chunk,
                          stable=stable, cost_impl=cost_impl)
    out = solve(problem, solver, key=key, validate=False)
    c = out.coupling
    return out.value, (c.rows, c.cols, c.vals)
