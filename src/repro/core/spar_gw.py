"""SPAR-GW — Algorithm 2 of the paper (paper-faithful COO implementation).

Sparse coupling supported on ``s`` importance-sampled index pairs
(p_ij ∝ sqrt(a_i b_j), eq. 5). Per-iteration work is O(s^2) cost assembly +
O(H s) sparse Sinkhorn. Static shapes throughout (TPU/JAX requirement):
``s`` is fixed and duplicates in S are legitimate parallel entries (the
segment-sum Sinkhorn merges them per row/col, preserving marginals).

The O(s²) cost assembly routes through the ``repro.kernels.spar_cost``
family via ``cost_impl`` ∈ {"auto", "jnp", "pallas", "materialized"}:
the kernels compute the affine form L-matvec(t) + off, so the whole
log-kernel logK = -(α/ε) L@T̃ + off (off folding log w, log T̃ and the FGW
linear term) is formed in one fused pass per outer iteration. SPAR-GW,
SPAR-FGW (and SPAR-UGW in spar_ugw.py) share the same outer step,
parameterized by the linear term. See DESIGN.md §3.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import sampling
from repro.core.sinkhorn import sparse_sinkhorn, sparse_sinkhorn_logdomain


def _cost_factory():
    # deferred: kernels.spar_cost.ref needs core.ground_cost, so a
    # module-level import here would be circular
    from repro.kernels.spar_cost.ops import make_spar_cost_fn
    return make_spar_cost_fn


def spar_cost(Cx, Cy, rows, cols, tvals, loss: str, chunk: int = 1024):
    """Reference COO cost assembly (kept as the public jnp oracle)."""
    from repro.kernels.spar_cost.ref import spar_cost_ref
    return spar_cost_ref(Cx, Cy, rows, cols, tvals, loss, chunk)


def _pga_step(T, cost_fn, a, b, rows, cols, w, logw, m: int, n: int,
              epsilon, inner_iters: int, reg: str, stable: bool,
              alpha=1.0, lin=0.0):
    """One proximal/entropic PGA outer step on the COO support.

    Shared by SPAR-GW (α = 1, lin = 0) and SPAR-FGW (lin = M̃): the
    iteration cost is C = α·(L @ T̃) + (1-α)·lin, and in the stable path
    the fused cost_fn writes logK = -C/ε + log w (+ log T̃) directly.
    """
    if stable:
        off = logw - ((1.0 - alpha) / epsilon) * lin
        if reg == "prox":
            off = off + jnp.log(jnp.maximum(T, 1e-38))
        logK = cost_fn((-alpha / epsilon) * T, off)
        return sparse_sinkhorn_logdomain(a, b, rows, cols, logK, m, n,
                                         inner_iters)
    C = cost_fn(alpha * T, (1.0 - alpha) * lin)
    Cs = C - jnp.min(C)          # constant shift — Sinkhorn-invariant
    K = jnp.exp(-Cs / epsilon) * w
    if reg == "prox":
        K = K * T
    return sparse_sinkhorn(a, b, rows, cols, K, m, n, inner_iters)


@partial(jax.jit,
         static_argnames=("s", "loss", "reg", "outer_iters", "inner_iters",
                          "cost_chunk", "stable", "cost_impl"))
def spar_gw(key, a, b, Cx, Cy, s: int, loss: str = "l2", reg: str = "prox",
            epsilon: float = 1e-2, outer_iters: int = 20,
            inner_iters: int = 50, shrink: float = 0.0,
            cost_chunk: int = 1024, stable: bool = True,
            cost_impl: str = "auto"):
    """Algorithm 2. Returns (gw_estimate, (rows, cols, coupling_values)).

    reg='prox' uses the Bregman proximal term KL(T‖T^(r)) (PGA);
    reg='ent' uses the entropic regularizer H(T). ``stable=True`` runs the
    sparse Sinkhorn in log domain (fp32-safe for small ε). ``cost_impl``
    selects the O(s²) cost-assembly backend (see module docstring).
    """
    m, n = Cx.shape[0], Cy.shape[0]
    probs = sampling.balanced_probs(a, b, shrink)
    rows, cols = sampling.sample_pairs(key, probs, s)
    p = probs.pair_prob(rows, cols)                     # (s,)
    w = 1.0 / (s * p)                                   # importance adjustment
    T = a[rows] * b[cols]                               # step 4 init on S
    cost_fn = _cost_factory()(Cx, Cy, rows, cols, loss, impl=cost_impl,
                              chunk=cost_chunk)
    step = partial(_pga_step, cost_fn=cost_fn, a=a, b=b, rows=rows,
                   cols=cols, w=w, logw=jnp.log(w), m=m, n=n,
                   epsilon=epsilon, inner_iters=inner_iters, reg=reg,
                   stable=stable)

    T, _ = lax.scan(lambda T, _: (step(T), None), T, None,
                    length=outer_iters)
    # Step 8: plug-in objective on the sparse support, O(s²).
    value = jnp.sum(T * cost_fn(T))
    return value, (rows, cols, T)


@partial(jax.jit,
         static_argnames=("s", "loss", "reg", "outer_iters", "inner_iters",
                          "cost_chunk", "stable", "cost_impl"))
def spar_fgw(key, a, b, Cx, Cy, M, s: int, alpha: float = 0.6,
             loss: str = "l2", reg: str = "prox", epsilon: float = 1e-2,
             outer_iters: int = 20, inner_iters: int = 50,
             shrink: float = 0.0, cost_chunk: int = 1024,
             stable: bool = True, cost_impl: str = "auto"):
    """SPAR-FGW — Algorithm 4 (appendix A). Fused GW with feature matrix M.

    C̃_fu(T̃) = α Σ L̃ T̃ + (1-α) M̃ on the sampled support.
    Returns (fgw_estimate, (rows, cols, coupling_values)).
    """
    m, n = Cx.shape[0], Cy.shape[0]
    probs = sampling.balanced_probs(a, b, shrink)
    rows, cols = sampling.sample_pairs(key, probs, s)
    p = probs.pair_prob(rows, cols)
    w = 1.0 / (s * p)
    Ms = M[rows, cols]                                  # M̃ on S
    T = a[rows] * b[cols]
    cost_fn = _cost_factory()(Cx, Cy, rows, cols, loss, impl=cost_impl,
                              chunk=cost_chunk)
    step = partial(_pga_step, cost_fn=cost_fn, a=a, b=b, rows=rows,
                   cols=cols, w=w, logw=jnp.log(w), m=m, n=n,
                   epsilon=epsilon, inner_iters=inner_iters, reg=reg,
                   stable=stable, alpha=alpha, lin=Ms)

    T, _ = lax.scan(lambda T, _: (step(T), None), T, None,
                    length=outer_iters)
    quad = jnp.sum(T * cost_fn(T))
    lin = jnp.sum(Ms * T)
    return alpha * quad + (1.0 - alpha) * lin, (rows, cols, T)
