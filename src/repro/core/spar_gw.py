"""SPAR-GW — Algorithm 2 of the paper (paper-faithful COO implementation).

Sparse coupling supported on ``s`` importance-sampled index pairs
(p_ij ∝ sqrt(a_i b_j), eq. 5). Per-iteration work is O(s^2) cost assembly +
O(H s) sparse Sinkhorn. Static shapes throughout (TPU/JAX requirement):
``s`` is fixed and duplicates in S are legitimate parallel entries (the
segment-sum Sinkhorn merges them per row/col, preserving marginals).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ground_cost as gc
from repro.core import sampling
from repro.core.sinkhorn import sparse_sinkhorn, sparse_sinkhorn_logdomain


def spar_cost(Cx, Cy, rows, cols, tvals, loss: str, chunk: int = 1024):
    """C̃(T̃)_k = Σ_l L(Cx[r_k, r_l], Cy[c_k, c_l]) T̃_l for k ∈ [s].  O(s²).

    Row-chunked so the gathered (chunk, s) blocks stay cache/VMEM-sized.
    """
    L = gc.get_loss(loss)
    s = rows.shape[0]
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    rows_p = jnp.pad(rows, (0, pad))
    cols_p = jnp.pad(cols, (0, pad))

    def one(args):
        rk, ck = args                      # (chunk,)
        Gx = Cx[rk][:, rows]               # (chunk, s)
        Gy = Cy[ck][:, cols]               # (chunk, s)
        return L(Gx, Gy) @ tvals           # (chunk,)

    out = lax.map(one, (rows_p.reshape(n_chunks, chunk),
                        cols_p.reshape(n_chunks, chunk)))
    return out.reshape(-1)[:s]


@partial(jax.jit,
         static_argnames=("s", "loss", "reg", "outer_iters", "inner_iters",
                          "cost_chunk", "stable"))
def spar_gw(key, a, b, Cx, Cy, s: int, loss: str = "l2", reg: str = "prox",
            epsilon: float = 1e-2, outer_iters: int = 20,
            inner_iters: int = 50, shrink: float = 0.0,
            cost_chunk: int = 1024, stable: bool = True):
    """Algorithm 2. Returns (gw_estimate, (rows, cols, coupling_values)).

    reg='prox' uses the Bregman proximal term KL(T‖T^(r)) (PGA);
    reg='ent' uses the entropic regularizer H(T). ``stable=True`` runs the
    sparse Sinkhorn in log domain (fp32-safe for small ε).
    """
    m, n = Cx.shape[0], Cy.shape[0]
    probs = sampling.balanced_probs(a, b, shrink)
    rows, cols = sampling.sample_pairs(key, probs, s)
    p = probs.pair_prob(rows, cols)                     # (s,)
    w = 1.0 / (s * p)                                   # importance adjustment
    T = a[rows] * b[cols]                               # step 4 init on S

    def outer(T, _):
        C = spar_cost(Cx, Cy, rows, cols, T, loss, cost_chunk)
        if stable:
            logK = -C / epsilon + jnp.log(w)
            if reg == "prox":
                logK = logK + jnp.log(jnp.maximum(T, 1e-38))
            T_new = sparse_sinkhorn_logdomain(a, b, rows, cols, logK, m, n,
                                              inner_iters)
        else:
            Cs = C - jnp.min(C)      # constant shift — Sinkhorn-invariant
            K = jnp.exp(-Cs / epsilon) * w
            if reg == "prox":
                K = K * T
            T_new = sparse_sinkhorn(a, b, rows, cols, K, m, n, inner_iters)
        return T_new, None

    T, _ = lax.scan(outer, T, None, length=outer_iters)
    # Step 8: plug-in objective on the sparse support, O(s²).
    C_final = spar_cost(Cx, Cy, rows, cols, T, loss, cost_chunk)
    value = jnp.sum(T * C_final)
    return value, (rows, cols, T)


@partial(jax.jit,
         static_argnames=("s", "loss", "reg", "outer_iters", "inner_iters",
                          "cost_chunk", "stable"))
def spar_fgw(key, a, b, Cx, Cy, M, s: int, alpha: float = 0.6,
             loss: str = "l2", reg: str = "prox", epsilon: float = 1e-2,
             outer_iters: int = 20, inner_iters: int = 50,
             shrink: float = 0.0, cost_chunk: int = 1024,
             stable: bool = True):
    """SPAR-FGW — Algorithm 4 (appendix A). Fused GW with feature matrix M.

    C̃_fu(T̃) = α Σ L̃ T̃ + (1-α) M̃ on the sampled support.
    Returns (fgw_estimate, (rows, cols, coupling_values)).
    """
    m, n = Cx.shape[0], Cy.shape[0]
    probs = sampling.balanced_probs(a, b, shrink)
    rows, cols = sampling.sample_pairs(key, probs, s)
    p = probs.pair_prob(rows, cols)
    w = 1.0 / (s * p)
    Ms = M[rows, cols]                                  # M̃ on S
    T = a[rows] * b[cols]

    def outer(T, _):
        C = alpha * spar_cost(Cx, Cy, rows, cols, T, loss, cost_chunk) \
            + (1.0 - alpha) * Ms
        if stable:
            logK = -C / epsilon + jnp.log(w)
            if reg == "prox":
                logK = logK + jnp.log(jnp.maximum(T, 1e-38))
            T_new = sparse_sinkhorn_logdomain(a, b, rows, cols, logK, m, n,
                                              inner_iters)
            return T_new, None
        Cs = C - jnp.min(C)
        K = jnp.exp(-Cs / epsilon) * w
        if reg == "prox":
            K = K * T
        T_new = sparse_sinkhorn(a, b, rows, cols, K, m, n, inner_iters)
        return T_new, None

    T, _ = lax.scan(outer, T, None, length=outer_iters)
    quad = jnp.sum(T * spar_cost(Cx, Cy, rows, cols, T, loss, cost_chunk))
    lin = jnp.sum(Ms * T)
    return alpha * quad + (1.0 - alpha) * lin, (rows, cols, T)
