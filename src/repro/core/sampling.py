"""Importance-sampling probabilities and samplers (paper §3.1, eq. 5 / 9).

The balanced probability p_ij ∝ sqrt(a_i b_j) is a *product measure*:
p_ij = (sqrt(a_i)/Z_a)(sqrt(b_j)/Z_b). We exploit this twice:
  · COO path — sample rows and cols independently per draw (exact i.i.d.
    draws from p with O(m+n) setup instead of O(mn));
  · grid path — sample a row set and a col set once and take the cross
    product (TPU-native; see core/grid_gw.py and DESIGN.md §4).

``shrink`` linearly interpolates toward the uniform distribution, which
enforces regularity condition (H.4): p_ij ≥ c3/n².
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class FactorizedProbs(NamedTuple):
    pa: jnp.ndarray   # (m,) row factor, sums to 1
    pb: jnp.ndarray   # (n,) col factor, sums to 1

    def pair_prob(self, rows, cols):
        return self.pa[rows] * self.pb[cols]


def balanced_probs(a, b, shrink: float = 0.0) -> FactorizedProbs:
    """Eq. (5): p_ij = sqrt(a_i b_j) / Σ sqrt(a_i b_j), factorized."""
    pa = jnp.sqrt(a)
    pa = pa / pa.sum()
    pb = jnp.sqrt(b)
    pb = pb / pb.sum()
    if shrink > 0.0:
        pa = (1 - shrink) * pa + shrink / a.shape[0]
        pb = (1 - shrink) * pb + shrink / b.shape[0]
    return FactorizedProbs(pa, pb)


def sample_pairs(key, probs: FactorizedProbs, s: int):
    """s i.i.d. pairs from the product measure (paper Alg. 2 step 3)."""
    kr, kc = jax.random.split(key)
    rows = jax.random.choice(kr, probs.pa.shape[0], (s,), p=probs.pa)
    cols = jax.random.choice(kc, probs.pb.shape[0], (s,), p=probs.pb)
    return rows, cols


def sample_grid(key, probs: FactorizedProbs, s_r: int, s_c: int):
    """Row set R (s_r i.i.d.) and col set C (s_c i.i.d.) for the grid path."""
    kr, kc = jax.random.split(key)
    R = jax.random.choice(kr, probs.pa.shape[0], (s_r,), p=probs.pa)
    C = jax.random.choice(kc, probs.pb.shape[0], (s_c,), p=probs.pb)
    return R, C


def unbalanced_probs(a, b, logK, lam: float, eps: float, shrink: float = 0.0):
    """Eq. (9): p_ij ∝ (a_i b_j)^{λ/(2λ+ε)} K_ij^{ε/(2λ+ε)}  (dense m×n).

    Takes log K for numerical robustness (the kernel at T⁰ underflows fp32
    for small ε); the normalization is computed with max-subtraction.
    """
    e1 = lam / (2 * lam + eps)
    e2 = eps / (2 * lam + eps)
    logab = jnp.log(jnp.maximum(a[:, None] * b[None, :], 1e-38))
    logP = e1 * logab + e2 * logK
    logP = logP - jnp.max(logP)
    P = jnp.exp(logP)
    P = P / P.sum()
    if shrink > 0.0:
        P = (1 - shrink) * P + shrink / (P.shape[0] * P.shape[1])
    return P


def sample_pairs_2d(key, P, s: int):
    """s i.i.d. index pairs from a dense 2-D probability matrix."""
    m, n = P.shape
    flat = jax.random.choice(key, m * n, (s,), p=P.reshape(-1))
    return flat // n, flat % n


def poisson_mask(key, probs_flat, s: int):
    """Poisson subsampling (appendix B): keep element ij w.p. min(1, s p_ij).

    Returned mask has E[nnz] ≤ s; used in tests to check expectation-
    equivalence with the fixed-size i.i.d. scheme.
    """
    p_star = jnp.minimum(1.0, s * probs_flat)
    return jax.random.uniform(key, probs_flat.shape) < p_star, p_star
