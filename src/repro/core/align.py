"""GW representation alignment for LM training — the paper's technique as a
first-class framework feature.

``gw_alignment_loss`` computes a differentiable entropic Grid-SPAR-GW
distance between the token-relation geometries of two hidden-state tensors
(teacher/student layers, or two models across incomparable spaces — the
embedding-alignment application the paper cites). Dense relation matrices
are S×S (16M entries at S=4k); importance sparsification makes the loss
O(s_r s_c) instead.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.grid_gw import grid_spar_gw_differentiable


def _pairwise_sq_dists(h):
    """(S, D) -> (S, S) squared euclidean relation matrix."""
    sq = jnp.sum(h * h, axis=-1)
    G = h @ h.T
    d = sq[:, None] + sq[None, :] - 2.0 * G
    return jnp.maximum(d, 0.0)


@partial(jax.jit, static_argnames=("s_r", "s_c", "outer_iters", "inner_iters"))
def gw_alignment_loss(key, h_x, h_y, s_r: int = 64, s_c: int = 64,
                      epsilon: float = 0.05, outer_iters: int = 3,
                      inner_iters: int = 10):
    """Batched GW distance between hidden geometries.

    h_x: (B, S, D_x), h_y: (B, S, D_y) — different widths are fine (GW
    compares relation matrices, not features). Returns scalar mean GW.
    """
    B, S, _ = h_x.shape

    def per_example(k, hx, hy):
        kr, kc = jax.random.split(k)
        R = jax.random.randint(kr, (s_r,), 0, S)
        C = jax.random.randint(kc, (s_c,), 0, S)
        hxn = hx / (jnp.linalg.norm(hx, axis=-1, keepdims=True) + 1e-6)
        hyn = hy / (jnp.linalg.norm(hy, axis=-1, keepdims=True) + 1e-6)
        CxR = _pairwise_sq_dists(hxn[R])
        CyC = _pairwise_sq_dists(hyn[C])
        aR = jnp.full((s_r,), 1.0 / s_r)
        bC = jnp.full((s_c,), 1.0 / s_c)
        w = jnp.ones((s_r, s_c))          # uniform measure -> uniform weights
        val, _ = grid_spar_gw_differentiable(
            aR, bC, CxR, CyC, aR, bC, w, "l2", epsilon, outer_iters,
            inner_iters)
        return val

    keys = jax.random.split(key, B)
    vals = jax.vmap(per_example)(keys, h_x, h_y)
    return jnp.mean(vals)
