"""Small shared utilities for the GW core."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def safe_div(num, den):
    """num / den with 0 where den == 0 (dead Sinkhorn rows/cols)."""
    return jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)


def chunked_rows(fn, n_rows: int, chunk: int):
    """Apply ``fn(start_index, chunk_size)`` over row chunks, concat results.

    ``n_rows`` and ``chunk`` are static; the last chunk is padded by fn's
    caller convention (we only use exact divisors or mask inside fn).
    """
    import numpy as np

    chunk = min(chunk, n_rows)
    n_chunks = -(-n_rows // chunk)
    outs = []
    for c in range(n_chunks):
        lo = c * chunk
        size = min(chunk, n_rows - lo)
        outs.append(fn(lo, size))
    return jnp.concatenate(outs, axis=0)


def total_mass(x) -> jnp.ndarray:
    return jnp.sum(x)


def generalized_kl(p, q):
    """KL(p || q) = sum p log(p/q) - m(p) + m(q) for nonnegative vectors."""
    eps = 1e-30
    p_ = jnp.maximum(p, eps)
    q_ = jnp.maximum(q, eps)
    return jnp.sum(p * (jnp.log(p_) - jnp.log(q_))) - jnp.sum(p) + jnp.sum(q)


def quadratic_kl(p, q):
    """KL^tensor(p||q) = KL(p (x) p || q (x) q) (Séjourné et al., 2021)."""
    mp, mq = jnp.sum(p), jnp.sum(q)
    eps = 1e-30
    cross = jnp.sum(p * (jnp.log(jnp.maximum(p, eps)) - jnp.log(jnp.maximum(q, eps))))
    return 2.0 * mp * cross - mp**2 + mq**2
