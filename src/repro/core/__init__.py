"""The paper's contribution: importance-sparsified GW distances in JAX."""
from repro.core.align import gw_alignment_loss
from repro.core.grid_gw import grid_cost, grid_spar_gw
from repro.core.gw import (
    dense_cost,
    egw,
    fgw_dense,
    gw_dense,
    gw_objective,
    pga_gw,
)
from repro.core.sagrow import sagrow
from repro.core.sinkhorn import (
    sinkhorn,
    sinkhorn_log,
    sinkhorn_unbalanced,
    sparse_sinkhorn,
    sparse_sinkhorn_unbalanced,
)
from repro.core.spar_gw import spar_cost, spar_fgw, spar_gw
from repro.core.spar_ugw import spar_ugw, ugw_dense
