"""SPAR-UGW — Algorithm 3: importance sparsification for unbalanced GW.

UGW relaxes the marginal constraints via quadratic KL divergences
(Séjourné et al., 2021). The sampling probability (eq. 9) depends on the
kernel at the rank-one initialization T⁰ = a bᵀ / sqrt(m(a) m(b)); the
decomposable fast path computes it in O(mn), the general path in chunked
O(m²n²) — once, as in the paper.

All kernels are handled in log domain: the unbalanced Sinkhorn exponent
makes plain-domain iterations scale-sensitive (no min-subtraction trick
exists), so fp32 underflow would otherwise kill the coupling at small ε.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import sampling
from repro.core.gw import dense_cost
from repro.core.sinkhorn import (
    sinkhorn_unbalanced_log,
    sparse_sinkhorn_unbalanced_log,
)
from repro.core.spar_gw import _cost_factory, spar_cost
from repro.core.utils import quadratic_kl


def _marginal_penalty(T_rows_sum, T_cols_sum, a, b, lam):
    """E(T) = λ Σ_i log(μ_i/a_i) μ_i + λ Σ_j log(ν_j/b_j) ν_j (scalar)."""
    eps = 1e-30
    mu, nu = T_rows_sum, T_cols_sum
    t1 = jnp.sum(jnp.where(mu > 0, jnp.log(jnp.maximum(mu, eps) / a) * mu, 0.0))
    t2 = jnp.sum(jnp.where(nu > 0, jnp.log(jnp.maximum(nu, eps) / b) * nu, 0.0))
    return lam * (t1 + t2)


def ugw_value(a, b, Cx, Cy, rows, cols, T, lam, loss: str, cost_chunk=1024,
              cost_fn=None):
    """UGW objective on a sparse coupling (Alg. 3 step 11)."""
    m, n = a.shape[0], b.shape[0]
    mu = jax.ops.segment_sum(T, rows, num_segments=m)
    nu = jax.ops.segment_sum(T, cols, num_segments=n)
    if cost_fn is None:
        cost_fn = lambda t: spar_cost(Cx, Cy, rows, cols, t, loss, cost_chunk)
    quad = jnp.sum(T * cost_fn(T))
    return quad + lam * quadratic_kl(mu, a) + lam * quadratic_kl(nu, b)


@partial(jax.jit,
         static_argnames=("s", "loss", "outer_iters", "inner_iters",
                          "cost_chunk", "cost_impl"))
def spar_ugw(key, a, b, Cx, Cy, s: int, loss: str = "l2", lam: float = 1.0,
             epsilon: float = 1e-2, outer_iters: int = 20,
             inner_iters: int = 50, shrink: float = 0.0,
             cost_chunk: int = 1024, cost_impl: str = "auto"):
    """Algorithm 3. Returns (ugw_estimate, (rows, cols, coupling_values))."""
    m, n = Cx.shape[0], Cy.shape[0]
    ma, mb = jnp.sum(a), jnp.sum(b)
    scale = jnp.sqrt(ma * mb)

    # --- steps 2-3: dense rank-one init and its (log-)kernel — computed once
    T0 = a[:, None] * b[None, :] / scale
    m0 = jnp.sum(T0)
    C0 = dense_cost(Cx, Cy, T0, loss) + _marginal_penalty(
        T0.sum(1), T0.sum(0), a, b, lam)
    logK0 = -C0 / (epsilon * m0) + jnp.log(jnp.maximum(T0, 1e-38))

    # --- steps 4-5: sampling probability (eq. 9) and index set
    P = sampling.unbalanced_probs(a, b, logK0, lam, epsilon, shrink)
    rows, cols = sampling.sample_pairs_2d(key, P, s)
    p = P[rows, cols]
    logw = -jnp.log(s * jnp.maximum(p, 1e-38))
    T = a[rows] * b[cols] / scale
    cost_fn = _cost_factory()(Cx, Cy, rows, cols, loss, impl=cost_impl,
                              chunk=cost_chunk)

    def outer(T, _):
        mT = jnp.sum(T)
        eps_bar = epsilon * mT
        lam_bar = lam * mT
        mu = jax.ops.segment_sum(T, rows, num_segments=m)
        nu = jax.ops.segment_sum(T, cols, num_segments=n)
        # fused: logK = -(L@T̃ + penalty)/ε̄ + log T̃ + log w in one pass
        off = (-_marginal_penalty(mu, nu, a, b, lam) / eps_bar
               + jnp.log(jnp.maximum(T, 1e-38)) + logw)
        logK = cost_fn((-1.0 / eps_bar) * T, off)
        T_new = sparse_sinkhorn_unbalanced_log(
            a, b, rows, cols, logK, lam_bar, eps_bar, m, n, inner_iters)
        # step 10: mass rescaling
        T_new = jnp.sqrt(mT / jnp.maximum(jnp.sum(T_new), 1e-30)) * T_new
        return T_new, None

    T, _ = lax.scan(outer, T, None, length=outer_iters)
    value = ugw_value(a, b, Cx, Cy, rows, cols, T, lam, loss, cost_chunk,
                      cost_fn=cost_fn)
    return value, (rows, cols, T)


@partial(jax.jit,
         static_argnames=("loss", "outer_iters", "inner_iters"))
def ugw_dense(a, b, Cx, Cy, loss: str = "l2", lam: float = 1.0,
              epsilon: float = 1e-2, outer_iters: int = 20,
              inner_iters: int = 50):
    """Dense PGA-UGW baseline (the paper's benchmark for Fig. 3)."""
    T0 = a[:, None] * b[None, :] / jnp.sqrt(jnp.sum(a) * jnp.sum(b))

    def outer(T, _):
        mT = jnp.sum(T)
        eps_bar = epsilon * mT
        lam_bar = lam * mT
        C = dense_cost(Cx, Cy, T, loss) + _marginal_penalty(
            T.sum(1), T.sum(0), a, b, lam)
        logK = -C / eps_bar + jnp.log(jnp.maximum(T, 1e-38))
        T_new = sinkhorn_unbalanced_log(a, b, logK, lam_bar, eps_bar,
                                        inner_iters)
        T_new = jnp.sqrt(mT / jnp.maximum(jnp.sum(T_new), 1e-30)) * T_new
        return T_new, None

    T, _ = lax.scan(outer, T0, None, length=outer_iters)
    quad = jnp.sum(T * dense_cost(Cx, Cy, T, loss))
    val = quad + lam * quadratic_kl(T.sum(1), a) + lam * quadratic_kl(T.sum(0), b)
    return val, T


def naive_ugw_value(a, b, Cx, Cy, loss: str = "l2", lam: float = 1.0):
    """Naive transport plan T = a bᵀ baseline (paper Fig. 3)."""
    T = a[:, None] * b[None, :]
    quad = jnp.sum(T * dense_cost(Cx, Cy, T, loss))
    return quad + lam * quadratic_kl(T.sum(1), a) + lam * quadratic_kl(T.sum(0), b)
