"""SPAR-UGW — legacy entry points (deprecation shims) + UGW helpers.

UGW relaxes the marginal constraints via quadratic KL divergences
(Séjourné et al., 2021). The solver implementation lives in
``repro.api.solvers`` (the unbalanced branch of ``SparGWSolver`` /
``DenseGWSolver``); these shims keep the original signatures and bare
tuple returns. The objective helpers (`_marginal_penalty`, `ugw_value`,
`naive_ugw_value`) stay here — they are shared by the API layer and the
benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gw import dense_cost
from repro.core.spar_gw import _warn_deprecated, spar_cost
from repro.core.utils import quadratic_kl


def _marginal_penalty(T_rows_sum, T_cols_sum, a, b, lam):
    """E(T) = λ Σ_i log(μ_i/a_i) μ_i + λ Σ_j log(ν_j/b_j) ν_j (scalar)."""
    eps = 1e-30
    mu, nu = T_rows_sum, T_cols_sum
    t1 = jnp.sum(jnp.where(mu > 0, jnp.log(jnp.maximum(mu, eps) / a) * mu, 0.0))
    t2 = jnp.sum(jnp.where(nu > 0, jnp.log(jnp.maximum(nu, eps) / b) * nu, 0.0))
    return lam * (t1 + t2)


def ugw_value(a, b, Cx, Cy, rows, cols, T, lam, loss: str, cost_chunk=1024,
              cost_fn=None):
    """UGW objective on a sparse coupling (Alg. 3 step 11)."""
    m, n = a.shape[0], b.shape[0]
    mu = jax.ops.segment_sum(T, rows, num_segments=m)
    nu = jax.ops.segment_sum(T, cols, num_segments=n)
    if cost_fn is None:
        cost_fn = lambda t: spar_cost(Cx, Cy, rows, cols, t, loss, cost_chunk)
    quad = jnp.sum(T * cost_fn(T))
    return quad + lam * quadratic_kl(mu, a) + lam * quadratic_kl(nu, b)


def spar_ugw(key, a, b, Cx, Cy, s: int, loss: str = "l2", lam: float = 1.0,
             epsilon: float = 1e-2, outer_iters: int = 20,
             inner_iters: int = 50, shrink: float = 0.0,
             cost_chunk: int = 1024, cost_impl: str = "auto"):
    """Algorithm 3 (shim). Returns (ugw_estimate, (rows, cols, vals))."""
    from repro.api import Geometry, QuadraticProblem, SparGWSolver, solve
    _warn_deprecated("spar_ugw")
    problem = QuadraticProblem(Geometry(Cx, a, validate=False),
                               Geometry(Cy, b, validate=False),
                               loss=loss, lam=lam, validate=False)
    solver = SparGWSolver(s=s, epsilon=epsilon, outer_iters=outer_iters,
                          inner_iters=inner_iters, shrink=shrink,
                          cost_chunk=cost_chunk, cost_impl=cost_impl)
    out = solve(problem, solver, key=key, validate=False)
    c = out.coupling
    return out.value, (c.rows, c.cols, c.vals)


def ugw_dense(a, b, Cx, Cy, loss: str = "l2", lam: float = 1.0,
              epsilon: float = 1e-2, outer_iters: int = 20,
              inner_iters: int = 50):
    """Dense PGA-UGW baseline (shim; the paper's benchmark for Fig. 3)."""
    from repro.api import DenseGWSolver, Geometry, QuadraticProblem, solve
    _warn_deprecated("ugw_dense")
    problem = QuadraticProblem(Geometry(Cx, a, validate=False),
                               Geometry(Cy, b, validate=False),
                               loss=loss, lam=lam, validate=False)
    solver = DenseGWSolver(epsilon=epsilon, outer_iters=outer_iters,
                           inner_iters=inner_iters)
    out = solve(problem, solver, validate=False)
    return out.value, out.coupling


def naive_ugw_value(a, b, Cx, Cy, loss: str = "l2", lam: float = 1.0):
    """Naive transport plan T = a bᵀ baseline (paper Fig. 3)."""
    T = a[:, None] * b[None, :]
    quad = jnp.sum(T * dense_cost(Cx, Cy, T, loss))
    return quad + lam * quadratic_kl(T.sum(1), a) + lam * quadratic_kl(T.sum(0), b)
