"""Exact OT via linear programming (scipy HiGHS) and the EMD-GW baseline.

The paper's EMD-GW replaces Sinkhorn with an exact OT solve in each outer
iteration. LP size is O(mn) variables — usable at small n only (it is the
slowest baseline in the paper as well). NumPy/SciPy, not jitted.
"""
from __future__ import annotations

import numpy as np
from scipy.optimize import linprog
from scipy.sparse import csr_matrix

from repro.core.gw import dense_cost, gw_objective


def exact_ot(a: np.ndarray, b: np.ndarray, M: np.ndarray) -> np.ndarray:
    """min <M, T> s.t. T 1 = a, Tᵀ 1 = b, T ≥ 0 (one redundant row dropped)."""
    m, n = M.shape
    rows = []
    cols = []
    for i in range(m):
        rows.append(np.full(n, i))
        cols.append(np.arange(i * n, (i + 1) * n))
    for j in range(n - 1):
        rows.append(np.full(m, m + j))
        cols.append(np.arange(j, m * n, n))
    A = csr_matrix(
        (np.ones(sum(len(r) for r in rows)),
         (np.concatenate(rows), np.concatenate(cols))),
        shape=(m + n - 1, m * n),
    )
    rhs = np.concatenate([a, b[:-1]])
    res = linprog(M.reshape(-1), A_eq=A, b_eq=rhs, bounds=(0, None),
                  method="highs")
    if not res.success:
        raise RuntimeError(f"exact OT LP failed: {res.message}")
    return res.x.reshape(m, n)


def emd_gw(a, b, Cx, Cy, loss: str = "l2", outer_iters: int = 20):
    """EMD-GW: Algorithm 1 with the Sinkhorn projection replaced by exact OT."""
    import jax.numpy as jnp

    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    T = a[:, None] * b[None, :]
    for _ in range(outer_iters):
        C = np.asarray(dense_cost(jnp.asarray(Cx), jnp.asarray(Cy),
                                  jnp.asarray(T), loss))
        T_new = exact_ot(a, b, C)
        if np.abs(T_new - T).sum() < 1e-12:
            T = T_new
            break
        T = T_new
    val = float(gw_objective(jnp.asarray(Cx), jnp.asarray(Cy),
                             jnp.asarray(T), loss))
    return val, T
