"""Grid-SPAR-GW — TPU-native factorized importance sparsification (beyond-paper).

The paper's sampling probability (eq. 5) is a product measure
p_ij = (sqrt(a_i)/Z_a)(sqrt(b_j)/Z_b). Sampling a row set R (s_r i.i.d.
draws ∝ sqrt(a)) and a column set C (s_c i.i.d. draws ∝ sqrt(b)) and taking
the support S = R × C yields s = s_r·s_c pairs, each marginally distributed
exactly as p_ij — the importance-weighted estimator keeps its unbiasedness
(only pairwise dependence, i.e. a constant-factor variance term, changes;
measured in benchmarks/bench_grid_vs_coo.py).

The payoff: the sparse coupling becomes a *dense s_r × s_c sub-block*, so
every sparse op becomes a small dense op — cost assembly is two MXU matmuls
(decomposable L) or a blocked 4-D contraction (arbitrary L — the Pallas
``gw_cost`` kernel), Sinkhorn is dense matvecs with the kernel matrix
VMEM-resident. No scatter/gather in the iteration. See DESIGN.md §4.

Duplicate sampled indices are handled by splitting the marginal mass among
duplicates (matching the COO segment-sum semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ground_cost as gc
from repro.core.sinkhorn import sinkhorn


def grid_cost(CxR, CyC, T, loss: str, use_kernel: bool = False,
              k_chunk: int = 8, l_chunk: int = 8):
    """C̃[k,m] = Σ_{l,p} L(CxR[k,l], CyC[m,p]) T[l,p] on the grid support.

    Decomposable L → O(s_r² s_c + s_r s_c²) matmuls (MXU path).
    Arbitrary L → O(s_r² s_c²) blocked contraction; ``use_kernel`` routes to
    the Pallas kernel (TPU), else a jnp chunked fallback (CPU oracle).
    """
    dec = gc.get_decomposition(loss)
    if dec is not None:
        mu = T.sum(axis=1)
        nu = T.sum(axis=0)
        t1 = (dec.f1(CxR) @ mu)[:, None]
        t2 = (dec.f2(CyC) @ nu)[None, :]
        t3 = dec.h1(CxR) @ T @ dec.h2(CyC).T
        return t1 + t2 - t3
    if use_kernel:
        from repro.kernels.gw_cost.ops import gw_cost as gw_cost_kernel
        return gw_cost_kernel(CxR, CyC, T, loss)
    L = gc.get_loss(loss)
    s_r, s_c = T.shape
    while s_r % k_chunk != 0:
        k_chunk -= 1
    while s_r % l_chunk != 0:
        l_chunk -= 1

    def over_k(A_k):                       # A_k: (k_chunk, s_r)
        def over_l(lc, acc):
            A = lax.dynamic_slice_in_dim(A_k, lc * l_chunk, l_chunk, axis=1)
            Tl = lax.dynamic_slice_in_dim(T, lc * l_chunk, l_chunk, axis=0)
            # E: (k_chunk, l_chunk, s_c, s_c); contract over (l, p)
            E = L(A[:, :, None, None], CyC[None, None, :, :])
            return acc + jnp.einsum("abcd,bd->ac", E, Tl)
        n_l = s_r // l_chunk
        acc0 = jnp.zeros((A_k.shape[0], s_c), T.dtype)
        return lax.fori_loop(0, n_l, over_l, acc0)

    out = lax.map(over_k, CxR.reshape(s_r // k_chunk, k_chunk, s_r))
    return out.reshape(s_r, s_c)


def _dedup_marginal(idx, full_weight, n_total):
    """Split marginal mass among duplicate draws: a[idx]/count(idx)."""
    counts = jax.ops.segment_sum(jnp.ones_like(idx, jnp.float32), idx,
                                 num_segments=n_total)
    return full_weight[idx] / counts[idx]


def grid_spar_gw(key, a, b, Cx, Cy, s_r: int, s_c: int, loss: str = "l2",
                 reg: str = "prox", epsilon: float = 1e-2,
                 outer_iters: int = 20, inner_iters: int = 50,
                 shrink: float = 0.0, use_kernel: bool = False,
                 stable: bool = True):
    """Grid-structured SPAR-GW (shim). Returns (gw_estimate, (R, C, T_block)).

    The solver loop lives in ``repro.api.solvers.GridGWSolver``.
    """
    from repro.api import Geometry, GridGWSolver, QuadraticProblem, solve
    from repro.core.spar_gw import _warn_deprecated
    _warn_deprecated("grid_spar_gw")
    problem = QuadraticProblem(Geometry(Cx, a, validate=False),
                               Geometry(Cy, b, validate=False),
                               loss=loss, validate=False)
    solver = GridGWSolver(s_r=s_r, s_c=s_c, reg=reg, epsilon=epsilon,
                          outer_iters=outer_iters, inner_iters=inner_iters,
                          shrink=shrink, use_kernel=use_kernel, stable=stable)
    out = solve(problem, solver, key=key, validate=False)
    c = out.coupling
    return out.value, (c.rows, c.cols, c.block)


def grid_spar_gw_differentiable(a, b, CxR, CyC, aR, bC, w, loss: str,
                                epsilon: float, outer_iters: int,
                                inner_iters: int):
    """Differentiable core (entropic reg, scan-unrolled) for the alignment
    loss — takes pre-gathered sub-blocks so AD flows into CxR/CyC."""
    T0 = aR[:, None] * bC[None, :]

    def outer(T, _):
        Cmat = grid_cost(CxR, CyC, T, loss)
        Cs = Cmat - lax.stop_gradient(jnp.min(Cmat))
        K = jnp.exp(-Cs / epsilon) * w
        T_new = sinkhorn(aR, bC, K, inner_iters, differentiable=True)
        return T_new, None

    T, _ = lax.scan(outer, T0, None, length=outer_iters)
    return jnp.sum(T * grid_cost(CxR, CyC, T, loss)), T
