"""Ground cost functions L(x, y) and their decomposable forms.

A cost is *decomposable* (Peyré et al., 2016) when
``L(x, y) = f1(x) + f2(y) - h1(x) h2(y)``, which enables the O(n^2 m + m^2 n)
dense cost-assembly path and the two-matmul grid path. ``l1`` is the
paper's canonical *indecomposable* cost.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax.numpy as jnp

_KL_EPS = 1e-10


def l1(x, y):
    return jnp.abs(x - y)


def l2(x, y):
    return (x - y) ** 2


def kl(x, y):
    xs = jnp.maximum(x, _KL_EPS)
    ys = jnp.maximum(y, _KL_EPS)
    return x * (jnp.log(xs) - jnp.log(ys)) - x + y


class Decomposition(NamedTuple):
    f1: Callable
    f2: Callable
    h1: Callable
    h2: Callable


LOSSES = {"l1": l1, "l2": l2, "kl": kl}

DECOMPOSITIONS: dict[str, Optional[Decomposition]] = {
    "l1": None,
    # (x-y)^2 = x^2 + y^2 - x * 2y
    "l2": Decomposition(
        f1=lambda x: x**2, f2=lambda y: y**2, h1=lambda x: x, h2=lambda y: 2.0 * y
    ),
    # x log(x/y) - x + y = (x log x - x) + y - x log y
    "kl": Decomposition(
        f1=lambda x: x * jnp.log(jnp.maximum(x, _KL_EPS)) - x,
        f2=lambda y: y,
        h1=lambda x: x,
        h2=lambda y: jnp.log(jnp.maximum(y, _KL_EPS)),
    ),
}


def get_loss(name: str) -> Callable:
    return LOSSES[name]


def get_decomposition(name: str) -> Optional[Decomposition]:
    return DECOMPOSITIONS.get(name)
