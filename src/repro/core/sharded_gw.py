"""Distributed Grid-SPAR-GW — the paper's technique sharded over the mesh.

The O(n²) phase (relation sub-block gathers) and the O(s²) phase (cost
assembly + Sinkhorn on the s_r × s_c grid block) shard as:

  CxR (s_r, s_r): rows over 'data'            P('data', None)
  CyC (s_c, s_c): rows over 'model'           P('model', None)
  T   (s_r, s_c): 2-D block-sharded           P('data', 'model')

Cost assembly (decomposable L) is a distributed matmul chain; Sinkhorn
matvecs psum over the opposing axis. Everything is ``shard_map`` with
explicit collectives, so the collective schedule is visible to the
roofline (benchmarks/bench_gw_dryrun.py dry-runs this exact program on the
production mesh).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import ground_cost as gc


def _local_grid_cost_decomposable(dec, CxR_l, CyC_l, T_full_rows, T_full_cols,
                                  mu, nu):
    """Per-device cost block. CxR_l: (s_r/dp, s_r); CyC_l: (s_c/mp, s_c);
    T_full_rows: (s_r, s_c/mp) [gathered over data]; mu: (s_r,), nu: (s_c,).
    Returns local block (s_r/dp, s_c/mp)."""
    t1 = (dec.f1(CxR_l) @ mu)[:, None]                    # (s_r/dp, 1)
    t2 = (dec.f2(CyC_l) @ nu)[None, :]                    # (1, s_c/mp) local rows?
    # h-term: h1(CxR_l) @ T @ h2(CyC)^T, assembled from gathered pieces
    ht = dec.h1(CxR_l) @ T_full_rows                      # (s_r/dp, s_c/mp)?? see caller
    return t1, t2, ht


def make_sharded_grid_gw(mesh: Mesh, s_r: int, s_c: int, loss: str = "l2",
                         epsilon: float = 1e-2, outer_iters: int = 10,
                         inner_iters: int = 30, comm_dtype=None):
    """Returns a jit-able fn(CxR, CyC, aR, bC, w) -> (gw_value, T_block).

    Decomposable-loss path (the ``l2`` production configuration).

    Hillclimb lever (EXPERIMENTS.md §Perf):
    · ``comm_dtype=jnp.bfloat16`` — cast large gathers to bf16 on the wire.
    (A psum-of-partials h-term restructure was tried and is *invalid* here:
    both contraction and output dims of each hop live on the same mesh
    axis, so partials from different devices cover different output blocks
    — caught by the 4-device equivalence test; see §Perf iteration log.)
    """
    dec = gc.get_decomposition(loss)
    assert dec is not None, "sharded path implements decomposable costs"
    dp, mp = mesh.shape["data"], mesh.shape["model"]

    def _gather(x, axis_name, axis):
        """bf16-on-the-wire gather: the result STAYS in comm_dtype and is
        consumed by a mixed-precision dot (f32 accumulate) — converting
        back immediately would let XLA sink the convert before the gather
        and ship f32 anyway (observed on the CPU backend)."""
        if comm_dtype is not None:
            return lax.all_gather(x.astype(comm_dtype), axis_name, axis=axis,
                                  tiled=True)
        return lax.all_gather(x, axis_name, axis=axis, tiled=True)

    def _mmt(a, b_t):
        """a @ b_t.T with f32 accumulation regardless of operand dtype."""
        return jax.lax.dot_general(a, b_t, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    def solver(CxR_l, CyC_l, aR_l, bC_l, w_l):
        # locals: CxR_l (s_r/dp, s_r), CyC_l (s_c/mp, s_c),
        # aR_l (s_r/dp,), bC_l (s_c/mp,), w_l (s_r/dp, s_c/mp)
        f1x = dec.f1(CxR_l)                                # (s_r/dp, s_r)
        f2y = dec.f2(CyC_l)                                # (s_c/mp, s_c)
        h1x = dec.h1(CxR_l)
        h2y = dec.h2(CyC_l)
        la_l = jnp.log(jnp.maximum(aR_l, 1e-38))
        lb_l = jnp.log(jnp.maximum(bC_l, 1e-38))

        def cost(T_l):
            # marginals (global): psum partial sums over the opposing axis
            mu_l = jnp.sum(T_l, axis=1)                    # (s_r/dp,)
            mu_l = lax.psum(mu_l, "model")
            nu_l = jnp.sum(T_l, axis=0)                    # (s_c/mp,)
            nu_l = lax.psum(nu_l, "data")
            mu = lax.all_gather(mu_l, "data", tiled=True)  # (s_r,)
            nu = lax.all_gather(nu_l, "model", tiled=True) # (s_c,)
            t1 = (f1x @ mu)[:, None]                       # (s_r/dp, 1)
            t2 = (f2y @ nu)[None, :]                       # (1, s_c/mp)
            # h-term ht = h1(CxR) @ T @ h2(CyC)^T, block-sharded
            #   M_l = T_rows @ h2yᵀ — gather T over 'model' (full rows)
            #   ht  = h1x @ M_full — gather M over 'data' (full rows)
            T_rows = _gather(T_l, "model", 1)
            h2y_c = h2y.astype(T_rows.dtype)
            M_l = _mmt(T_rows, h2y_c)                      # (s_r/dp, s_c/mp) f32
            M_full = _gather(M_l, "data", 0)
            ht = jax.lax.dot_general(
                h1x.astype(M_full.dtype), M_full, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)        # (s_r/dp, s_c/mp)
            return t1 + t2 - ht

        def sinkhorn_log_block(logK_l):
            f_l = jnp.zeros_like(aR_l)
            g_l = jnp.zeros_like(bC_l)

            def body(_, fg):
                f_l, g_l = fg
                # row lse: over full s_c — local partial + psum-max trick:
                z = logK_l + g_l[None, :]
                m_l = lax.pmax(jnp.max(z, axis=1), "model")
                sums = lax.psum(jnp.sum(jnp.exp(z - m_l[:, None]), axis=1),
                                "model")
                f_l = la_l - (jnp.log(jnp.maximum(sums, 1e-38)) + m_l)
                z = logK_l + f_l[:, None]
                m_c = lax.pmax(jnp.max(z, axis=0), "data")
                sums = lax.psum(jnp.sum(jnp.exp(z - m_c[None, :]), axis=0),
                                "data")
                g_l = lb_l - (jnp.log(jnp.maximum(sums, 1e-38)) + m_c)
                return (f_l, g_l)

            f_l, g_l = lax.fori_loop(0, inner_iters, body, (f_l, g_l))
            return jnp.exp(logK_l + f_l[:, None] + g_l[None, :])

        T_l = aR_l[:, None] * bC_l[None, :]
        def outer(_, T_l):
            C_l = cost(T_l)
            logK_l = -C_l / epsilon + jnp.log(w_l) \
                + jnp.log(jnp.maximum(T_l, 1e-38))
            return sinkhorn_log_block(logK_l)

        T_l = lax.fori_loop(0, outer_iters, outer, T_l)
        val = lax.psum(lax.psum(jnp.sum(cost(T_l) * T_l), "model"), "data")
        return val, T_l

    sharded = shard_map(
        solver, mesh=mesh,
        in_specs=(P("data", None), P("model", None), P("data"), P("model"),
                  P("data", "model")),
        out_specs=(P(), P("data", "model")),
        check_rep=False,
    )
    return jax.jit(sharded)
