"""Dense GW cost assembly + legacy Algorithm 1 entry points (shims).

`dense_cost` / `gw_objective` are the shared primitives (O(n^2 m + m^2 n)
per iteration for decomposable ground costs, chunked O(m^2 n^2) for
arbitrary costs). The solver loops live in
``repro.api.solvers.DenseGWSolver``; `gw_dense` / `fgw_dense` / `egw` /
`pga_gw` are deprecation shims with the original signatures.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import ground_cost as gc


def dense_cost(Cx, Cy, T, loss: str, row_chunk: int = 8):
    """C(T)_ij = Σ_{i',j'} L(Cx_ii', Cy_jj') T_i'j'  — tensor-matrix product.

    Decomposable costs use the Peyré decomposition; arbitrary costs use a
    row-chunked O(m^2 n^2) contraction (the paper's motivating bottleneck).
    """
    dec = gc.get_decomposition(loss)
    if dec is not None:
        mu = T.sum(axis=1)            # row marginal
        nu = T.sum(axis=0)            # col marginal
        term1 = (dec.f1(Cx) @ mu)[:, None]
        term2 = (dec.f2(Cy) @ nu)[None, :]
        term3 = dec.h1(Cx) @ T @ dec.h2(Cy).T
        return term1 + term2 - term3
    L = gc.get_loss(loss)
    m = Cx.shape[0]
    n = Cy.shape[0]

    def one_chunk(Cx_chunk):
        # Cx_chunk: (c, m) -> (c, n)
        E = L(Cx_chunk[:, :, None, None], Cy[None, None, :, :])  # (c, m, n, n)
        return jnp.einsum("abcd,bd->ac", E, T)

    n_chunks = -(-m // row_chunk)
    pad = n_chunks * row_chunk - m
    Cx_p = jnp.pad(Cx, ((0, pad), (0, 0)))
    out = lax.map(one_chunk, Cx_p.reshape(n_chunks, row_chunk, m))
    return out.reshape(n_chunks * row_chunk, n)[:m]


def gw_objective(Cx, Cy, T, loss: str, row_chunk: int = 8):
    """⟨L(Cx,Cy) ⊗ T, T⟩."""
    return jnp.sum(dense_cost(Cx, Cy, T, loss, row_chunk) * T)


def gw_dense(a, b, Cx, Cy, loss: str = "l2", reg: str = "prox",
             epsilon: float = 1e-2, outer_iters: int = 20,
             inner_iters: int = 50, stable: bool = True):
    """Algorithm 1 (shim): EGW (reg='ent') or PGA-GW (reg='prox').

    ``stable=True`` runs the Sinkhorn projection in log domain (required for
    small ε / proximal kernels in fp32); ``stable=False`` is the plain-domain
    algorithm exactly as written in the paper. Returns (gw_value, T).
    """
    from repro.api import DenseGWSolver, Geometry, QuadraticProblem, solve
    from repro.core.spar_gw import _warn_deprecated
    _warn_deprecated("gw_dense")
    problem = QuadraticProblem(Geometry(Cx, a, validate=False),
                               Geometry(Cy, b, validate=False),
                               loss=loss, validate=False)
    solver = DenseGWSolver(reg=reg, epsilon=epsilon, outer_iters=outer_iters,
                           inner_iters=inner_iters, stable=stable)
    out = solve(problem, solver, validate=False)
    return out.value, out.coupling


def egw(a, b, Cx, Cy, **kw):
    kw.setdefault("reg", "ent")
    return gw_dense(a, b, Cx, Cy, **kw)


def pga_gw(a, b, Cx, Cy, **kw):
    kw.setdefault("reg", "prox")
    return gw_dense(a, b, Cx, Cy, **kw)


def fgw_dense(a, b, Cx, Cy, M, alpha: float = 0.6, loss: str = "l2",
              reg: str = "prox", epsilon: float = 1e-2, outer_iters: int = 20,
              inner_iters: int = 50, stable: bool = True):
    """Dense fused GW (shim; appendix A baseline): C_fu = α L⊗T + (1-α) M."""
    from repro.api import DenseGWSolver, Geometry, QuadraticProblem, solve
    from repro.core.spar_gw import _warn_deprecated
    _warn_deprecated("fgw_dense")
    problem = QuadraticProblem(Geometry(Cx, a, validate=False),
                               Geometry(Cy, b, validate=False),
                               loss=loss, fused_penalty=alpha, M=M,
                               validate=False)
    solver = DenseGWSolver(reg=reg, epsilon=epsilon, outer_iters=outer_iters,
                           inner_iters=inner_iters, stable=stable)
    out = solve(problem, solver, validate=False)
    return out.value, out.coupling


def entropic_gw_value(Cx, Cy, T, loss: str, epsilon: float):
    """GW_eps = <C(T), T> + eps * H(T) for the entropic variant."""
    ent = jnp.sum(jnp.where(T > 0, T * jnp.log(jnp.maximum(T, 1e-38)), 0.0))
    return gw_objective(Cx, Cy, T, loss) + epsilon * ent
