"""Dense GW solvers — the paper's Algorithm 1 (EGW / PGA-GW) and helpers.

These are the baselines the paper compares against (Peyré et al. 2016;
Xu et al. 2019b). They are O(n^2 m + m^2 n) per iteration for decomposable
ground costs and O(m^2 n^2) (chunked) for arbitrary costs.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ground_cost as gc
from repro.core.sinkhorn import sinkhorn, sinkhorn_log


def dense_cost(Cx, Cy, T, loss: str, row_chunk: int = 8):
    """C(T)_ij = Σ_{i',j'} L(Cx_ii', Cy_jj') T_i'j'  — tensor-matrix product.

    Decomposable costs use the Peyré decomposition; arbitrary costs use a
    row-chunked O(m^2 n^2) contraction (the paper's motivating bottleneck).
    """
    dec = gc.get_decomposition(loss)
    if dec is not None:
        mu = T.sum(axis=1)            # row marginal
        nu = T.sum(axis=0)            # col marginal
        term1 = (dec.f1(Cx) @ mu)[:, None]
        term2 = (dec.f2(Cy) @ nu)[None, :]
        term3 = dec.h1(Cx) @ T @ dec.h2(Cy).T
        return term1 + term2 - term3
    L = gc.get_loss(loss)
    m = Cx.shape[0]
    n = Cy.shape[0]

    def one_chunk(Cx_chunk):
        # Cx_chunk: (c, m) -> (c, n)
        E = L(Cx_chunk[:, :, None, None], Cy[None, None, :, :])  # (c, m, n, n)
        return jnp.einsum("abcd,bd->ac", E, T)

    n_chunks = -(-m // row_chunk)
    pad = n_chunks * row_chunk - m
    Cx_p = jnp.pad(Cx, ((0, pad), (0, 0)))
    out = lax.map(one_chunk, Cx_p.reshape(n_chunks, row_chunk, m))
    return out.reshape(n_chunks * row_chunk, n)[:m]


def gw_objective(Cx, Cy, T, loss: str, row_chunk: int = 8):
    """⟨L(Cx,Cy) ⊗ T, T⟩."""
    return jnp.sum(dense_cost(Cx, Cy, T, loss, row_chunk) * T)


@partial(jax.jit, static_argnames=("loss", "reg", "outer_iters", "inner_iters",
                                   "stable"))
def gw_dense(a, b, Cx, Cy, loss: str = "l2", reg: str = "prox",
             epsilon: float = 1e-2, outer_iters: int = 20,
             inner_iters: int = 50, stable: bool = True):
    """Algorithm 1: EGW (reg='ent') or PGA-GW (reg='prox').

    ``stable=True`` runs the Sinkhorn projection in log domain (required for
    small ε / proximal kernels in fp32); ``stable=False`` is the plain-domain
    algorithm exactly as written in the paper. Returns (gw_value, T).
    """
    T0 = a[:, None] * b[None, :]

    def outer(T, _):
        C = dense_cost(Cx, Cy, T, loss)
        if stable:
            logK = -C / epsilon
            if reg == "prox":
                logK = logK + jnp.log(jnp.maximum(T, 1e-38))
            T_new = sinkhorn_log(a, b, logK, inner_iters)
        else:
            Cs = C - jnp.min(C)          # constant shift — Sinkhorn-invariant
            K = jnp.exp(-Cs / epsilon)
            if reg == "prox":
                K = K * T
            T_new = sinkhorn(a, b, K, inner_iters)
        return T_new, None

    T, _ = lax.scan(outer, T0, None, length=outer_iters)
    val = gw_objective(Cx, Cy, T, loss)
    return val, T


def egw(a, b, Cx, Cy, **kw):
    kw.setdefault("reg", "ent")
    return gw_dense(a, b, Cx, Cy, **kw)


def pga_gw(a, b, Cx, Cy, **kw):
    kw.setdefault("reg", "prox")
    return gw_dense(a, b, Cx, Cy, **kw)


@partial(jax.jit, static_argnames=("loss", "reg", "outer_iters", "inner_iters",
                                   "stable"))
def fgw_dense(a, b, Cx, Cy, M, alpha: float = 0.6, loss: str = "l2",
              reg: str = "prox", epsilon: float = 1e-2, outer_iters: int = 20,
              inner_iters: int = 50, stable: bool = True):
    """Dense fused GW (appendix A baseline): C_fu = α L⊗T + (1-α) M."""
    T0 = a[:, None] * b[None, :]

    def outer(T, _):
        C = alpha * dense_cost(Cx, Cy, T, loss) + (1 - alpha) * M
        if stable:
            logK = -C / epsilon
            if reg == "prox":
                logK = logK + jnp.log(jnp.maximum(T, 1e-38))
            return sinkhorn_log(a, b, logK, inner_iters), None
        Cs = C - jnp.min(C)
        K = jnp.exp(-Cs / epsilon)
        if reg == "prox":
            K = K * T
        return sinkhorn(a, b, K, inner_iters), None

    T, _ = lax.scan(outer, T0, None, length=outer_iters)
    val = alpha * gw_objective(Cx, Cy, T, loss) + (1 - alpha) * jnp.sum(M * T)
    return val, T


def entropic_gw_value(Cx, Cy, T, loss: str, epsilon: float):
    """GW_eps = <C(T), T> + eps * H(T) for the entropic variant."""
    ent = jnp.sum(jnp.where(T > 0, T * jnp.log(jnp.maximum(T, 1e-38)), 0.0))
    return gw_objective(Cx, Cy, T, loss) + epsilon * ent
