from repro.optim.adamw import (
    AdamWState,
    abstract_state,
    cosine_schedule,
    init,
    state_axes,
    update,
)
