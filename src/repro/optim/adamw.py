"""AdamW with decoupled weight decay, global-norm clipping, schedules."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def init(params) -> AdamWState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.zeros_like, params))


def abstract_state(abstract_params) -> AdamWState:
    z = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                     abstract_params)
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32), z, z)


def state_axes(param_axes) -> AdamWState:
    """Optimizer state shards exactly like its parameters."""
    return AdamWState((), param_axes, param_axes)


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def update(grads, state: AdamWState, params, lr, b1=0.9, b2=0.95, eps=1e-8,
           weight_decay=0.1, max_grad_norm=1.0):
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    m = jax.tree.map(lambda mu, g: b1 * mu + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda nu, g: b2 * nu + (1 - b2) * g * g, state.v, grads)

    def upd(p, mu, nu):
        mh = mu / bc1
        vh = nu / bc2
        return p - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step, m, v), gnorm


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac=0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr
