"""Deterministic synthetic token pipeline — shard-aware and checkpointable.

Production shape: an index-based iterator where batch ``i`` is a pure
function of (seed, step) — so restarts are bit-exact (the step rides in the
checkpoint), data-parallel shards slice the same global batch, and elastic
re-scaling just re-slices. A real deployment swaps `_synthesize` for
tokenized shard files; every other property (determinism, shardability,
checkpointability) is what actually matters at scale and is tested.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass
class DataConfig:
    seed: int = 1234
    # markov-chain synthetic language (so CE actually decreases in examples)
    order_bias: float = 0.8


class TokenPipeline:
    def __init__(self, cfg: ArchConfig, seq_len: int, global_batch: int,
                 data_cfg: Optional[DataConfig] = None):
        self.cfg = cfg
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.dc = data_cfg or DataConfig()
        self.step = 0

    # -- state (checkpointable) --------------------------------------------
    def state_dict(self) -> Dict:
        return {"step": self.step, "seed": self.dc.seed}

    def load_state_dict(self, state: Dict):
        self.step = int(state["step"])
        self.dc.seed = int(state["seed"])

    # -- batches -------------------------------------------------------------
    def _synthesize(self, rng: np.random.Generator, batch: int):
        V = self.cfg.vocab_size
        S = self.seq_len + 1
        # cheap markov-ish stream: next token correlated with previous
        base = rng.integers(0, V, size=(batch, S), dtype=np.int64)
        keep = rng.random((batch, S)) < self.dc.order_bias
        toks = base.copy()
        for t in range(1, S):
            toks[:, t] = np.where(keep[:, t],
                                  (toks[:, t - 1] * 31 + 7) % V,
                                  base[:, t])
        return toks.astype(np.int32)

    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Batch `step` — pure function of (seed, step)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.dc.seed, step]))
        toks = self._synthesize(rng, self.global_batch)
        if self.cfg.n_codebooks > 1:
            C = self.cfg.n_codebooks
            toks = np.stack([(toks * (c + 1) + c) % self.cfg.vocab_size
                             for c in range(C)], axis=-1)
            batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        else:
            batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.family == "vlm":
            img = rng.standard_normal(
                (self.global_batch, self.cfg.n_image_tokens,
                 self.cfg.d_model)).astype(np.float32)
            batch["image_embeds"] = img
        return batch

    def shard_slice(self, batch: Dict, shard_index: int, num_shards: int):
        """Per-host slice of the global batch (multi-host data loading)."""
        per = self.global_batch // num_shards
        lo = shard_index * per
        return {k: v[lo:lo + per] for k, v in batch.items()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.global_batch_at(self.step)
        self.step += 1
        return b
