"""Block-local expansion of a coarse coupling — multiscale stage 3.

The coarse solve produces an anchor-level coupling T̃ (k_x × k_y). For
each of the ``max_pairs`` heaviest anchor pairs (c, d), refinement runs an
entropic Sinkhorn between the *member distributions* of clusters c and d.
The local ground cost is the **linearized GW cost** around the
block-constant expansion T⁰ of the coarse coupling (T⁰ = Σ_{c,d} T̃[c,d]
u_c v_dᵀ with u_c, v_d the member distributions):

    E[i, j] = Σ_{i', j'} L(Cx[i, i'], Cy[j, j']) · T⁰[i', j']

i.e. the exact first-order cost of matching i → j given the anchor-level
correspondence. For decomposable losses L = f1 + f2 - h1·h2 this
factorizes into f1(Cx)·a and f2(Cy)·b (exact fine marginal terms) plus a
rank-k cross term (h1(Cx)·P_u) T̃ (h2(Cy)·P_v)ᵀ through the membership
matrices — O(m²·k) matmuls, gathered per block. Indecomposable losses
fall back to the distance-to-anchor profile cost L(d(x_i, x_c),
d(y_j, y_d)) (the per-pair local alignment signal), which needs no
full-resolution sum. For fused problems the (1-α)-weighted linear term
restricted to the block is added in both cases.

Each local coupling has marginals (a|_c / ã_c, b|_d / b̃_d), so scaling by
T̃[c, d] and summing blocks yields a fine coupling whose marginals match
(a, b) up to the coarse solve's own marginal violation, coarse mass
outside the kept pairs, and members beyond the table cap.

All blocks share the static shape (cap_x, cap_y) (padded slots get weight
~0, zeroed exactly on emission), so the B local solves are one
``vmap``-ed ``sinkhorn_log`` and the whole stage jits/vmaps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.api.output import QuantizedCoupling
from repro.core import ground_cost as gc
from repro.core.sinkhorn import sinkhorn_log
from repro.multiscale.anchors import (
    AnchorAssignment,
    member_table,
    membership,
)

_TINY = 1e-38
# padded member slots get this weight instead of exact 0: XLA CPU flushes
# subnormals, so the 1e-38 floor inside sinkhorn_log would become log(0) =
# -inf and _finite would clamp the padded potentials to 0 — handing padded
# slots full kernel mass. 1e-30 is a normal float32, keeping the padded
# log-weights finite (≈ -69) and the padded coupling mass ≈ 1e-30.
_PAD_WEIGHT = 1e-30


def top_pairs(Tc, max_pairs: int):
    """The ``max_pairs`` heaviest entries of the coarse coupling."""
    ky = Tc.shape[1]
    mass, flat = lax.top_k(Tc.reshape(-1), max_pairs)
    return flat // ky, flat % ky, mass


def _member_side(cost, weights, anchors: AnchorAssignment, cap: int):
    """Padded member data for one side: indices, weights, anchor-distance
    columns (all (k, cap)-shaped, padded slots down-weighted to ~0)."""
    k = anchors.indices.shape[0]
    table, _ = member_table(anchors.assign, k, cap)
    mask = table >= 0
    safe = jnp.where(mask, table, 0)
    w = jnp.where(mask, weights[safe], 0.0)
    w = jnp.maximum(w / jnp.maximum(w.sum(axis=1, keepdims=True), _TINY),
                    _PAD_WEIGHT)
    prof = jnp.where(mask, cost[safe, anchors.indices[:, None]], 0.0)
    return safe, mask, w, prof


def _linearized_factors(problem, ax, ay, Tc):
    """The rank-k factorization of the linearized GW cost E around the
    block-constant expansion T⁰ (decomposable losses):
    E[i, j] = t1[i] + t2[j] - (Gx @ T̃ @ Gyᵀ)[i, j]."""
    dec = gc.get_decomposition(problem.loss)
    Cx, a = problem.geom_x.cost_matrix, problem.geom_x.weights
    Cy, b = problem.geom_y.cost_matrix, problem.geom_y.weights
    t1 = dec.f1(Cx) @ a                              # (m,)  μ(T⁰) = a exactly
    t2 = dec.f2(Cy) @ b                              # (n,)
    Gx = dec.h1(Cx) @ membership(ax, a)              # (m, k_x)
    Gy = dec.h2(Cy) @ membership(ay, b)              # (n, k_y)
    Mid = Tc @ Gy.T                                  # (k_x, n)
    return t1, t2, Gx, Mid


def block_refine(problem, ax: AnchorAssignment, ay: AnchorAssignment, Tc,
                 *, cap_x: int, cap_y: int, max_pairs: int, epsilon,
                 iters: int, tol: float) -> QuantizedCoupling:
    """Expand the coarse coupling Tc into a ``QuantizedCoupling``."""
    Cx, a = problem.geom_x.cost_matrix, problem.geom_x.weights
    Cy, b = problem.geom_y.cost_matrix, problem.geom_y.weights
    fused = problem.is_fused
    alpha = problem.fused_penalty if fused else 1.0
    decomposable = gc.get_decomposition(problem.loss) is not None

    tx, mask_x, u, dx = _member_side(Cx, a, ax, cap_x)
    ty, mask_y, v, dy = _member_side(Cy, b, ay, cap_y)
    pr, pc, mass = top_pairs(Tc, max_pairs)
    if decomposable:
        t1, t2, Gx, Mid = _linearized_factors(problem, ax, ay, Tc)
    else:
        L = gc.get_loss(problem.loss)

    def one_block(c, d):
        mx, my = tx[c], ty[d]
        if decomposable:
            E = t1[mx][:, None] + t2[my][None, :] - Gx[mx] @ Mid[:, my]
        else:
            E = L(dx[c][:, None], dy[d][None, :])
        if fused:
            E = alpha * E + (1.0 - alpha) * problem.linear_cost_at(
                mx[:, None], my[None, :])
        return sinkhorn_log(u[c], v[d], -E / epsilon, iters, tol=tol)

    blocks = jax.vmap(one_block)(pr, pc) * mass[:, None, None]
    # zero the (≈1e-30-mass) padded slots exactly; padded member index -> 0
    blocks = blocks * mask_x[pr][:, :, None] * mask_y[pc][:, None, :]
    return QuantizedCoupling(pr, pc, tx[pr], ty[pc], blocks)
