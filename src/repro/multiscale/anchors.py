"""Anchor selection for the multiscale (quantized) GW pipeline — stage 1.

Compress each metric-measure space to k ≪ n *anchors* (Chowdhury et al.,
2021): a deterministic, key-driven pipeline working purely on the pairwise
cost matrix (no coordinates required, so it covers graphs as well as point
clouds):

  1. **farthest-point sampling** — the first anchor is drawn from the
     marginal (the only use of the PRNG key; everything downstream is
     deterministic given it), each subsequent anchor maximizes the minimum
     cost to the anchors chosen so far;
  2. **weighted medoid refinement** — Lloyd iterations adapted to
     metric-measure data: assign every point to its nearest anchor, then
     move each anchor to the member minimizing the marginal-weighted sum
     of costs to its cluster (k-medoids, since only the cost matrix is
     available — no barycenters to average).

Everything is ``lax``-native (``fori_loop`` + argmin/argmax), so anchor
selection traces once and runs inside ``jit``/``vmap`` like the rest of
``repro.solve``.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


class AnchorAssignment(NamedTuple):
    """Anchors for one geometry: k representatives + a hard partition.

    indices — (k,) int32 anchor *point* indices into the parent geometry
    assign  — (n,) int32 cluster id in [0, k) for every point
    weights — (k,) aggregated marginal mass per anchor (Σ of member weights;
              sums to the total mass of the parent marginal)
    """
    indices: Any
    assign: Any
    weights: Any


def farthest_point_sampling(key, D, weights, k: int):
    """k anchor indices: random weighted start, then greedy max-min cost."""
    start = jax.random.categorical(key, jnp.log(jnp.maximum(weights, 1e-38)))
    idx0 = jnp.zeros((k,), jnp.int32).at[0].set(start.astype(jnp.int32))
    mind0 = D[start].at[start].set(-jnp.inf)   # chosen points never re-picked

    def body(i, state):
        idx, mind = state
        nxt = jnp.argmax(mind).astype(jnp.int32)
        return idx.at[i].set(nxt), jnp.minimum(mind, D[nxt]).at[nxt].set(-jnp.inf)

    idx, _ = lax.fori_loop(1, k, body, (idx0, mind0))
    return idx


def fps_points(key, points, weights, k: int):
    """Coordinate-space farthest-point sampling — O(n·k·d), no cost
    matrix. Same contract as :func:`farthest_point_sampling` (random
    weighted start, greedy max-min squared-euclidean), for callers that
    must never materialize the n×n cost — e.g. the low-rank solver's
    anchor-seeded init (lowrank/init.py). Returns (indices (k,) int32,
    assign (n,) int32 nearest-anchor partition)."""
    n = points.shape[0]
    start = jax.random.categorical(key, jnp.log(jnp.maximum(weights, 1e-38)))

    def d2(j):
        return jnp.sum((points - points[j]) ** 2, axis=-1)

    idx0 = jnp.zeros((k,), jnp.int32).at[0].set(start.astype(jnp.int32))
    mind0 = d2(start).at[start].set(-jnp.inf)
    assign0 = jnp.zeros((n,), jnp.int32)

    def body(i, state):
        idx, mind, assign = state
        nxt = jnp.argmax(mind).astype(jnp.int32)
        dn = d2(nxt)
        assign = jnp.where(dn < mind, i, assign)   # -inf slots keep owner
        mind = jnp.minimum(mind, dn).at[nxt].set(-jnp.inf)
        return idx.at[i].set(nxt), mind, assign

    idx, _, assign = lax.fori_loop(1, k, body, (idx0, mind0, assign0))
    # chosen anchors' own slots were frozen at -inf; pin them to themselves
    assign = assign.at[idx].set(jnp.arange(k, dtype=jnp.int32))
    return idx, assign


def medoid_refinement(D, weights, indices, iters: int):
    """Weighted Lloyd/k-medoids rounds on the cost matrix.

    Each round: assign points to the nearest current anchor, then for each
    cluster pick the member j minimizing Σ_{i∈cluster} w_i D[j, i]. Empty
    clusters (possible after duplicate draws on e.g. 0/1 adjacency costs)
    keep their anchor. Returns (indices, assign).
    """
    k = indices.shape[0]

    def body(_, idx):
        assign = jnp.argmin(D[:, idx], axis=1)
        member = jax.nn.one_hot(assign, k, dtype=D.dtype)          # (n, k)
        scores = D @ (weights[:, None] * member)                   # (n, k)
        scores = jnp.where(member > 0, scores, jnp.inf)
        new = jnp.argmin(scores, axis=0).astype(idx.dtype)
        empty = jnp.sum(member, axis=0) == 0
        return jnp.where(empty, idx, new)

    indices = lax.fori_loop(0, iters, body, indices)
    assign = jnp.argmin(D[:, indices], axis=1).astype(jnp.int32)
    return indices, assign


def select_anchors(key, D, weights, k: int, method: str = "fps",
                   refine_iters: int = 2) -> AnchorAssignment:
    """Pick k anchors of the space (D, weights) and partition the points.

    method — "fps" (farthest-point start, the default) or "random"
             (k weighted draws without replacement; baseline)
    """
    if method == "fps":
        idx = farthest_point_sampling(key, D, weights, k)
    elif method == "random":
        idx = jax.random.choice(key, D.shape[0], (k,), replace=False,
                                p=weights).astype(jnp.int32)
    else:
        raise ValueError(f"unknown anchor method {method!r} "
                         f"(known: fps, random)")
    idx, assign = medoid_refinement(D, weights, idx, refine_iters)
    wk = jax.ops.segment_sum(weights, assign, num_segments=k)
    return AnchorAssignment(idx, assign, wk)


def membership(anchors: AnchorAssignment, weights):
    """Conditional membership matrix P (n, k): P[i, c] = w_i/w̃_c · 1[i ∈ c].

    Columns are the member distributions of each cluster (each sums to 1);
    used for mean-metric compression and the cluster-averaged linearized
    refinement cost.
    """
    k = anchors.indices.shape[0]
    cond = weights / jnp.maximum(anchors.weights[anchors.assign], 1e-38)
    return jax.nn.one_hot(anchors.assign, k, dtype=weights.dtype) * cond[:, None]


def member_table(assign, k: int, cap: int):
    """Padded member lists: table[c, slot] = point index, -1 where padded.

    Every point gets the slot equal to its rank (by point index) within
    its cluster; points ranked ≥ cap are *dropped* from the table (their
    mass is excluded from refinement and shows up as marginal violation —
    size cap generously, see QuantizedGWSolver.max_members). Returns
    (table (k, cap) int32, dropped_mask (n,) bool).
    """
    n = assign.shape[0]
    order = jnp.argsort(assign)                       # stable: groups clusters
    counts = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), assign,
                                 num_segments=k)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32) - starts[assign[order]])
    slot = jnp.minimum(rank, cap)                     # cap → out of bounds
    table = jnp.full((k, cap), -1, jnp.int32).at[assign, slot].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")
    return table, rank >= cap
