"""Geometry/problem compression onto anchors — multiscale stage 2.

Builds the anchor-level ``QuadraticProblem``: compress each cost matrix to
k×k, aggregate marginal mass per cluster, and collapse the fused linear
term per anchor pair. Two metric compressions:

* ``"mean"`` (default) — C̃[c, c'] = E_{i∈c, i'∈c'}[C[i, i']], the
  conditional average under the member distributions (two matmuls through
  the membership matrix). Variance-reduced: the coarse objective of a
  block-constant coupling matches the fine objective of its expansion up
  to within-cluster variance of L (not of C), which measurably tightens
  the quantization bias of the coarse GW value.
* ``"anchor"`` — the anchor row/column submatrix C[idx][:, idx]
  (Chowdhury et al.'s representative-point quantization; cheaper, O(k²)
  gathers, no m² work).

An explicit fused linear term M aggregates to the conditional average
(a constant M stays that constant, and the coarse fused objective is
exact for block-constant couplings). Feature-derived fused terms
instead aggregate the *features* to cluster means, so the coarse
linear cost ||f̄_c - f̄_d||² undercounts the conditional average by the
within-cluster feature variances (Jensen) — a deliberate trade to keep
the (m, n) linear cost unmaterialized; pass an explicit M when that
bias matters. The compressed problem is an ordinary
``QuadraticProblem`` — any registered solver can run on it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api.geometry import Geometry
from repro.api.problem import QuadraticProblem
from repro.core import ground_cost as gc
from repro.multiscale.anchors import AnchorAssignment, membership

_TINY = 1e-38
# empty clusters (possible after duplicate medoid draws on e.g. 0/1
# adjacency costs) aggregate to weight exactly 0, and XLA CPU flushes
# subnormals — log(max(0, 1e-38)) inside the coarse Sinkhorn would be
# -inf, get clamped to 0 by _finite, and hand the empty anchor kernel
# mass that refinement then silently drops. Floor at a *normal* float32
# (same defect class as refine._PAD_WEIGHT).
_EMPTY_ANCHOR_WEIGHT = 1e-30


def compress_geometry(geom: Geometry, anchors: AnchorAssignment,
                      metric: str = "mean") -> Geometry:
    """The k-point quantized space: compressed cost + aggregated weights.

    Features (when present) are aggregated to the cluster's weighted mean,
    so a feature-derived fused term stays feature-derived at the coarse
    level without ever materializing the (m, n) linear cost.
    """
    if metric == "mean":
        P = membership(anchors, geom.weights)
        cost = P.T @ geom.cost_matrix @ P
    elif metric == "anchor":
        idx = anchors.indices
        cost = geom.cost_matrix[idx][:, idx]
    else:
        raise ValueError(f"unknown compress metric {metric!r} "
                         f"(known: mean, anchor)")
    feats = None
    if geom.features is not None:
        k = anchors.indices.shape[0]
        wsum = jax.ops.segment_sum(
            geom.weights[:, None] * geom.features, anchors.assign,
            num_segments=k)
        feats = wsum / jnp.maximum(anchors.weights, _TINY)[:, None]
    weights = jnp.maximum(anchors.weights, _EMPTY_ANCHOR_WEIGHT)
    return Geometry(cost, weights, feats, validate=False)


def compress_linear_cost(M, ax: AnchorAssignment, ay: AnchorAssignment,
                         a, b):
    """M̃[c, d] = E_{i∈c, j∈d}[M_ij] under the member distributions."""
    return membership(ax, a).T @ M @ membership(ay, b)


def compress_problem(problem: QuadraticProblem, ax: AnchorAssignment,
                     ay: AnchorAssignment,
                     metric: str = "mean") -> QuadraticProblem:
    """The anchor-level problem: same loss/variant structure, k_x × k_y size."""
    gx = compress_geometry(problem.geom_x, ax, metric)
    gy = compress_geometry(problem.geom_y, ay, metric)
    Mk = None
    if problem.M is not None:
        Mk = compress_linear_cost(problem.M, ax, ay,
                                  problem.geom_x.weights,
                                  problem.geom_y.weights)
    return QuadraticProblem(gx, gy, loss=problem.loss,
                            fused_penalty=problem.fused_penalty, M=Mk,
                            lam=problem.lam, validate=False)


def coarse_value_correction(problem: QuadraticProblem,
                            coarse_problem: QuadraticProblem):
    """Debias of the coarse GW value: within-cluster cost-variance terms.

    A balanced coarse coupling T̃ stands for its block-constant expansion
    T⁰, whose marginals are exactly (a, b). For a decomposable loss the
    f-terms of the fine objective of *any* such coupling are therefore the
    constants ⟨f1(Cx) a, a⟩ + ⟨f2(Cy) b, b⟩ — but the coarse objective
    computes them on the compressed costs, ⟨f1(C̃x) ã, ã⟩ + ⟨f2(C̃y) b̃, b̃⟩,
    undercounting by the within-cluster variance of the cost under the
    member distributions (Jensen: f1 convex for the square loss, and
    ⟨f1(C̃) ã, ã⟩ = f1 of a conditional average where the fine term
    averages f1). The correction swaps the coarse constants for the exact
    fine ones:

        Δ = ⟨f1(Cx) a, a⟩ - ⟨f1(C̃x) ã, ã⟩ + ⟨f2(Cy) b, b⟩ - ⟨f2(C̃y) b̃, b̃⟩.

    For the square loss with the "mean" metric the h-cross term is linear
    in C, so compression introduces no bias there and ``coarse.value + Δ``
    is *exactly* the fine objective of the block-constant expansion —
    which is what makes ``value_mode="coarse"`` quantitatively
    trustworthy at scale (ROADMAP "debiased estimator" item). Two O(m²)
    matvecs per side, no m×n object. Returns None for indecomposable
    losses (no f/h split to correct).
    """
    dec = gc.get_decomposition(problem.loss)
    if dec is None:
        return None
    a, b = problem.geom_x.weights, problem.geom_y.weights
    ca, cb = coarse_problem.geom_x.weights, coarse_problem.geom_y.weights
    fine = (jnp.dot(a, dec.f1(problem.geom_x.cost_matrix) @ a)
            + jnp.dot(b, dec.f2(problem.geom_y.cost_matrix) @ b))
    coarse = (jnp.dot(ca, dec.f1(coarse_problem.geom_x.cost_matrix) @ ca)
              + jnp.dot(cb, dec.f2(coarse_problem.geom_y.cost_matrix) @ cb))
    return fine - coarse
