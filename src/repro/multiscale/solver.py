"""``QuantizedGWSolver`` — multiscale: compress → solve → refine → polish.

Quantized GW (Chowdhury et al., 2021) on top of the unified API: compress
both spaces to k ≈ √n anchors (anchors.py), solve the k × k anchor
problem with *any registered base solver* (the ``base`` field nests a
solver config — dense_gw by default, spar_gw for large k), expand the
coarse coupling block-locally (refine.py), and optionally *polish* —
a few proximal PGA steps with the exact O(s²) support cost (the paper's
SPAR-GW machinery pointed at the refined support instead of a sampled
one), which lets mass move across blocks and is what closes the last few
percent to the dense solution. Total cost is O(m²·k) compression +
k-level solve + O(B·cap²) refinement (+ O(s²) per polish step), instead
of the O(n³)-per-iteration cost of solving at full resolution — this is
the n ≥ 10k regime opener.

The config is a pytree whose dynamic leaves are ``epsilon`` (refinement /
polish temperature) and the nested ``base`` solver's own leaves, so ε
sweeps at either level never retrace. Sizing fields left at defaults are
resolved from the problem shape at trace time (shapes are static under
jit).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.api.driver import pga_loop
from repro.api.output import GWOutput
from repro.api.pytree import register_pytree_dataclass
from repro.api.solvers import (
    DenseGWSolver,
    _coo_marginal_err,
    _require_key,
    _spar_pga_step,
    register_solver,
)
from repro.core.gw import gw_objective
from repro.health.loop import tree_finite
from repro.health.status import CONVERGED, DIVERGED, MAXITER, SolveStatus
from repro.kernels.spar_cost.ops import make_spar_cost_fn
from repro.multiscale.anchors import select_anchors
from repro.multiscale.compress import coarse_value_correction, compress_problem
from repro.multiscale.refine import block_refine

# dense refined-value evaluation allowed up to this many coupling entries
_REFINED_VALUE_MAX = 512 * 512
# auto-polish runs while the refined support stays below this size (each
# polish step assembles the exact support cost, O(s²))
_POLISH_MAX_SUPPORT = 32768

# anchor problems are k×k (k ≈ √n), so a heavy inner budget is cheap — and
# necessary: an unconverged inner Sinkhorn stalls the coarse PGA at a
# non-coupling fixed point whose marginal violation the refinement inherits
_DEFAULT_BASE = DenseGWSolver(epsilon=1e-2, outer_iters=50, inner_iters=2000,
                              tol=1e-6, inner_tol=1e-8)


def _materialized(problem):
    """Point-cloud geometries densified once up front: the pipeline reads
    ``cost_matrix`` from half a dozen stages, and while XLA CSEs the
    rebuilds under jit, eager callers would pay the O(n²·d) assembly per
    access."""
    if problem.geom_x.cost is not None and problem.geom_y.cost is not None:
        return problem
    from repro.api.geometry import Geometry
    from repro.api.problem import QuadraticProblem

    def dense(g):
        return Geometry(g.cost_matrix, g.weights, g.features,
                        validate=False)

    return QuadraticProblem(dense(problem.geom_x), dense(problem.geom_y),
                            loss=problem.loss,
                            fused_penalty=problem.fused_penalty,
                            M=problem.M, lam=problem.lam, validate=False)


def _auto_k(n: int) -> int:
    return min(n, max(16, math.isqrt(n - 1) + 1))        # ⌈√n⌉, floor 16


def _auto_cap(n: int, k: int) -> int:
    return min(n, max(8, -(-3 * n // k)))                # 3× mean cluster size


@dataclass(frozen=True)
class QuantizedGWSolver:
    """Multiscale quantized GW: compress → base solve → refine → polish.

    k_x, k_y      — anchor counts (0 → ⌈√n⌉ with a floor of 16)
    max_members   — member-table cap per cluster (0 → 3× mean cluster size;
                    members past the cap are dropped from refinement and
                    surface as marginal violation)
    max_pairs     — refined anchor pairs (0 → 2(k_x + k_y), ≈ 2× the LP
                    support bound of the coarse coupling)
    anchor_method — "fps" (farthest-point + medoid refinement) or "random"
    anchor_iters  — weighted-medoid refinement rounds
    compress_metric — "mean" (conditional-average anchor costs, variance-
                    reduced) or "anchor" (submatrix; skips the m²k matmuls)
    base          — nested solver config for the anchor-level problem; any
                    registered solver instance, or a registry name string
                    (resolved at construction). Sampling bases with s=0 are
                    auto-sized for the coarse problem at trace time.
    epsilon       — entropic temperature of the block-local refinement
                    Sinkhorn and the polish steps (dynamic leaf)
    refine_iters, refine_tol — budget/tolerance of each local Sinkhorn
    polish_iters  — exact-support-cost proximal PGA steps after refinement
                    (balanced problems only): -1 → auto (5 steps while the
                    support is ≤ 32768 entries, else none), 0 → off
    polish_inner_iters — inner Sinkhorn budget per polish step
    value_mode    — "coarse" reports the anchor-level objective (the
                    quantized-GW estimate, always available); "refined"
                    evaluates the true objective of the output coupling
                    (via the O(s²) support cost when polishing, else by
                    densifying — small problems only); "auto" picks
                    refined whenever polish ran or m·n ≤ 512², coarse
                    otherwise (and always for unbalanced problems)
    debias        — apply the within-cluster cost-variance correction to
                    reported coarse values (compress.coarse_value_
                    correction): swaps the compressed f-terms for the
                    exact fine ones, making the coarse estimate the exact
                    fine objective of the block-constant expansion for
                    the square loss. Balanced decomposable problems only
                    (no-op otherwise). Two O(m²) matvecs when it fires.
    max_rescues, rescue_factor — ε-rescue budget of the *polish* loop
                    (the coarse solve inherits the nested base solver's
                    own rescue config)
    fault         — chaos-testing hook targeting the polish loop; to
                    poison the coarse solve, set ``fault`` on the nested
                    ``base`` config instead (health/faults.py)
    trace         — record per-iteration convergence buffers for the
                    *coarse* (anchor-level) solve onto ``output.trace``
                    (forwarded to the nested ``base`` solver when it
                    supports tracing; the fixed-budget refine/polish
                    stages are not loop-traced)
    """
    k_x: int = 0
    k_y: int = 0
    max_members: int = 0
    max_pairs: int = 0
    anchor_method: str = "fps"
    anchor_iters: int = 2
    compress_metric: str = "mean"
    base: Any = _DEFAULT_BASE
    epsilon: Any = 5e-2
    refine_iters: int = 200
    refine_tol: float = 1e-8
    polish_iters: int = -1
    polish_inner_iters: int = 500
    value_mode: str = "auto"
    debias: bool = True
    max_rescues: int = 2
    rescue_factor: float = 2.0
    fault: Any = None
    trace: bool = False

    requires_key = True

    def __post_init__(self):
        if isinstance(self.base, str):
            from repro.api.solvers import get_solver
            object.__setattr__(self, "base", get_solver(self.base)())
        if self.value_mode not in ("auto", "coarse", "refined"):
            raise ValueError(
                f"value_mode must be auto|coarse|refined, got "
                f"{self.value_mode!r}")

    @classmethod
    def default_config(cls, n: int):
        return cls()

    # -- sizing (trace-time: problem shapes are static) ---------------------

    def _resolve(self, m: int, n: int):
        kx = min(self.k_x or _auto_k(m), m)
        ky = min(self.k_y or _auto_k(n), n)
        cap_x = min(self.max_members or _auto_cap(m, kx), m)
        cap_y = min(self.max_members or _auto_cap(n, ky), n)
        pairs = min(self.max_pairs or 2 * (kx + ky), kx * ky)
        return kx, ky, cap_x, cap_y, pairs

    def _sized_base(self, kx: int, ky: int):
        """Auto-size sampling bases left unconfigured for the coarse shape."""
        base = self.base
        if getattr(base, "s", None) == 0:
            base = dataclasses.replace(base, s=16 * max(kx, ky))
        if getattr(base, "s_r", None) == 0:
            side = type(base).default_config(max(kx, ky))
            base = dataclasses.replace(base, s_r=side.s_r, s_c=side.s_c)
        if self.trace and getattr(base, "trace", None) is False:
            base = dataclasses.replace(base, trace=True)
        return base

    def _polish_budget(self, support: int, balanced: bool) -> int:
        if not balanced:
            if self.polish_iters > 0:
                raise NotImplementedError(
                    "polish is balanced-only (proximal PGA on the support "
                    "assumes coupling marginals); set polish_iters=0 for "
                    "unbalanced problems")
            return 0
        if self.polish_iters >= 0:
            return self.polish_iters
        return 5 if support <= _POLISH_MAX_SUPPORT else 0

    # -- pipeline -----------------------------------------------------------

    def run(self, problem, key=None) -> GWOutput:
        _require_key(key, "QuantizedGWSolver")
        problem = _materialized(problem)
        m, n = problem.shape
        kx, ky, cap_x, cap_y, pairs = self._resolve(m, n)
        key_ax, key_ay, key_base = jax.random.split(key, 3)

        ax = select_anchors(key_ax, problem.geom_x.cost_matrix,
                            problem.geom_x.weights, kx,
                            method=self.anchor_method,
                            refine_iters=self.anchor_iters)
        ay = select_anchors(key_ay, problem.geom_y.cost_matrix,
                            problem.geom_y.weights, ky,
                            method=self.anchor_method,
                            refine_iters=self.anchor_iters)

        coarse_problem = compress_problem(problem, ax, ay,
                                          self.compress_metric)
        coarse = self._sized_base(kx, ky).run(coarse_problem, key_base)
        Tc = coarse.coupling_dense(kx, ky)

        coupling = block_refine(problem, ax, ay, Tc, cap_x=cap_x,
                                cap_y=cap_y, max_pairs=pairs,
                                epsilon=self.epsilon,
                                iters=self.refine_iters, tol=self.refine_tol)

        piters = self._polish_budget(pairs * cap_x * cap_y,
                                     not problem.is_unbalanced)
        if piters > 0:
            coupling, value, polish_status = self._polish(problem, coupling,
                                                          piters)
            if self.value_mode == "coarse":
                value = self._coarse_value(problem, coarse_problem, coarse)
        else:
            polish_status = None
            value = self._value(problem, coarse_problem, coarse, coupling,
                                m, n)
        status = self._combined_status(coarse, polish_status, value, coupling)
        return GWOutput(value=value, coupling=coupling, errors=coarse.errors,
                        converged=coarse.converged, n_iters=coarse.n_iters,
                        status=status,
                        trace=getattr(coarse, "trace", None))

    def _combined_status(self, coarse, polish_status, value, coupling):
        """Join the stage verdicts: the coarse solve's status is the
        baseline; the polish (a fixed-budget refinement, so its MAXITER
        is by design) only contributes divergence; a final finite-guard
        on the output catches anything the uninstrumented refinement
        stage produced."""
        status = coarse.status
        if status is None:      # third-party base without health plumbing
            status = SolveStatus(
                code=jnp.where(coarse.converged, CONVERGED,
                               MAXITER).astype(jnp.int32),
                fail_iter=jnp.int32(-1), last_err=jnp.float32(jnp.nan),
                n_rescues=jnp.int32(0))
        if polish_status is not None:
            status = status.join(polish_status._replace(
                code=jnp.where(polish_status.is_diverged, DIVERGED,
                               CONVERGED).astype(jnp.int32)))
        ok = tree_finite((value, coupling))
        return status.join(SolveStatus(
            code=jnp.where(ok, CONVERGED, DIVERGED).astype(jnp.int32),
            fail_iter=jnp.int32(-1), last_err=jnp.float32(jnp.nan),
            n_rescues=jnp.int32(0)))

    # -- polish: exact-support-cost proximal PGA (SPAR-GW machinery) --------

    def _polish(self, problem, coupling, piters: int):
        a = problem.geom_x.weights
        b = problem.geom_y.weights
        m, n = problem.shape
        rows, cols, vals = coupling.tocoo()
        in_support = vals > 0
        cost_fn = make_spar_cost_fn(problem.geom_x.cost_matrix,
                                    problem.geom_y.cost_matrix,
                                    rows, cols, problem.loss)
        fused = problem.is_fused
        alpha = problem.fused_penalty if fused else 1.0
        lin = problem.linear_cost_at(rows, cols) if fused else 0.0
        # padded/underflowed entries enter at 1e-30: the proximal kernel
        # carries log T̃, so they stay ~0 relative to the live support
        T0 = jnp.maximum(vals, 1e-30)
        step = partial(_spar_pga_step, cost_fn=cost_fn, a=a, b=b, rows=rows,
                       cols=cols, w=jnp.ones_like(vals),
                       logw=jnp.zeros_like(vals), m=m, n=n,
                       epsilon=self.epsilon,
                       inner_iters=self.polish_inner_iters,
                       inner_tol=self.refine_tol, reg="prox", stable=True,
                       alpha=alpha, lin=lin)
        err_fn = partial(_coo_marginal_err, rows=rows, cols=cols, a=a, b=b)
        T, _, _, _, status, _ = pga_loop(
            step, err_fn, T0, piters, 0.0, scaled_step=True,
            max_rescues=self.max_rescues, rescue_factor=self.rescue_factor,
            fault=self.fault)
        T = jnp.where(in_support, T, 0.0)
        quad = jnp.sum(T * cost_fn(T))        # exact ⟨L⊗T, T⟩ on the support
        if fused:
            value = alpha * quad + (1.0 - alpha) * jnp.sum(lin * T)
        else:
            value = quad
        blocks = T.reshape(coupling.blocks.shape)
        return coupling._replace(blocks=blocks), value, status

    # -- value without polish ----------------------------------------------

    def _coarse_value(self, problem, coarse_problem, coarse):
        """The anchor-level objective, debiased when the structure allows
        (balanced decomposable problems; see compress.coarse_value_
        correction — unbalanced coarse values use the coupling's own
        marginals, which the correction's constant-f-term identity does
        not cover)."""
        if not self.debias or problem.is_unbalanced:
            return coarse.value
        correction = coarse_value_correction(problem, coarse_problem)
        if correction is None:
            return coarse.value
        if problem.is_fused:
            # the f-terms enter the fused objective α-weighted
            # (C_fu = α·L⊗T + (1-α)·M); the explicit-M linear term
            # aggregates exactly, so only the quadratic gap is corrected
            correction = problem.fused_penalty * correction
        return coarse.value + correction

    def _value(self, problem, coarse_problem, coarse, coupling, m: int,
               n: int):
        refined_ok = not problem.is_unbalanced
        if self.value_mode == "refined" and not refined_ok:
            raise NotImplementedError(
                "value_mode='refined' is balanced-only (the refined "
                "unbalanced objective needs dense marginal-KL terms); use "
                "value_mode='coarse' for unbalanced problems")
        if self.value_mode == "refined" and m * n > _REFINED_VALUE_MAX:
            raise ValueError(
                f"value_mode='refined' without polish densifies the "
                f"({m}, {n}) coupling; only supported up to "
                f"{_REFINED_VALUE_MAX} entries — use value_mode='coarse' "
                f"(the quantized-GW estimate) instead")
        use_refined = self.value_mode == "refined" or (
            self.value_mode == "auto" and refined_ok
            and m * n <= _REFINED_VALUE_MAX)
        if not use_refined:
            return self._coarse_value(problem, coarse_problem, coarse)
        T = coupling.todense(m, n)
        quad = gw_objective(problem.geom_x.cost_matrix,
                            problem.geom_y.cost_matrix, T, problem.loss)
        if problem.is_fused:
            alpha = problem.fused_penalty
            return alpha * quad + (1.0 - alpha) * jnp.sum(
                problem.linear_cost_dense() * T)
        return quad


register_pytree_dataclass(
    QuantizedGWSolver,
    data_fields=("epsilon", "base", "fault"),
    meta_fields=("k_x", "k_y", "max_members", "max_pairs", "anchor_method",
                 "anchor_iters", "compress_metric", "refine_iters",
                 "refine_tol", "polish_iters", "polish_inner_iters",
                 "value_mode", "debias", "max_rescues", "rescue_factor",
                 "trace"))
register_solver("quantized_gw")(QuantizedGWSolver)
