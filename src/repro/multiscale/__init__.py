"""Multiscale (quantized) GW subsystem — anchor-compress → solve → refine.

DESIGN.md §6. The pipeline stages are reusable on their own:
``anchors.select_anchors`` (FPS + weighted medoid refinement),
``compress.compress_problem`` (anchor-level QuadraticProblem), and
``refine.block_refine`` (block-local Sinkhorn expansion). The registered
``quantized_gw`` solver (:class:`QuantizedGWSolver`) composes them with
any registered base solver for the anchor-level solve.
"""
from repro.multiscale.anchors import (
    AnchorAssignment,
    farthest_point_sampling,
    medoid_refinement,
    member_table,
    membership,
    select_anchors,
)
from repro.multiscale.compress import (
    coarse_value_correction,
    compress_geometry,
    compress_linear_cost,
    compress_problem,
)
from repro.multiscale.refine import block_refine, top_pairs
from repro.multiscale.solver import QuantizedGWSolver

__all__ = [
    "AnchorAssignment",
    "select_anchors",
    "farthest_point_sampling",
    "medoid_refinement",
    "member_table",
    "membership",
    "coarse_value_correction",
    "compress_geometry",
    "compress_linear_cost",
    "compress_problem",
    "block_refine",
    "top_pairs",
    "QuantizedGWSolver",
]
