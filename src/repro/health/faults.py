"""Fault injection — a chaos harness for the numerical-health machinery.

Detection and recovery paths that only fire on real divergence are
untestable on healthy problems, so every solver config carries an
optional ``fault`` field (a :class:`FaultSpec`) that the shared loop
driver applies at configured iterations:

    solver = DenseGWSolver(fault=FaultSpec(at_iter=3, kind="nan"))
    out = repro.solve(problem, solver)      # diverges at iteration 3
    assert out.status.describe() == "DIVERGED" or out.status.n_rescues > 0

``at_iter`` is a *dynamic* pytree leaf: under ``vmap`` it can be a
per-lane value, so a stacked solve can poison exactly one lane
(``at_iter=-1`` disarms a lane) — the per-lane-independence acceptance
test. ``kind``/``site``/``persistent`` are static metadata (they select
code, not data).

For the multiscale solver, ``QuantizedGWSolver.fault`` targets the
polish loop; to poison the coarse solve, set the fault on the nested
``base`` solver config instead (faults compose exactly like solvers do).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.api.pytree import register_pytree_dataclass

_KINDS = ("nan", "inf", "overflow", "zero")
_SITES = ("iterate", "cost")

# finite but huge: squares/products overflow fp32 downstream, so the
# fault is *not* caught at the injection step — it exercises the
# detection of divergence that develops over following iterations
_OVERFLOW_SCALE = 1e30


@dataclass(frozen=True)
class FaultSpec:
    """Inject a numerical fault into the outer loop at chosen iterations.

    at_iter    — iteration index to fire at (0-based; dynamic leaf, may be
                 a per-lane scalar under vmap; negative = never fire)
    kind       — "nan" / "inf": poison every entry of the iterate;
                 "overflow": scale by 1e30 (finite now, overflows later);
                 "zero": wipe the iterate (mass-collapse path)
    site       — "iterate": applied to the step's *output* (a poisoned
                 update); "cost": applied to the step's *input*, so the
                 fault flows through the cost evaluation / inner Sinkhorn
    persistent — fire at every iteration >= at_iter instead of once
                 (a once-off fault is rescuable by restarting; a
                 persistent one exhausts rescue and must end DIVERGED)
    """
    at_iter: Any = -1
    kind: str = "nan"
    site: str = "iterate"
    persistent: bool = False

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, got "
                             f"{self.kind!r}")
        if self.site not in _SITES:
            raise ValueError(f"site must be one of {_SITES}, got "
                             f"{self.site!r}")

    def fires(self, i):
        at = jnp.asarray(self.at_iter)
        hit = (i >= at) if self.persistent else (i == at)
        return hit & (at >= 0)

    def apply(self, tree, i):
        """Poison every leaf of ``tree`` when the fault fires at ``i``."""
        hit = self.fires(i)

        def poison(x):
            if self.kind == "nan":
                bad = jnp.full_like(x, jnp.nan)
            elif self.kind == "inf":
                bad = jnp.full_like(x, jnp.inf)
            elif self.kind == "zero":
                bad = jnp.zeros_like(x)
            else:  # overflow
                bad = x * jnp.asarray(_OVERFLOW_SCALE, x.dtype)
            return jnp.where(hit, bad, x)

        return jax.tree.map(poison, tree)


register_pytree_dataclass(FaultSpec, data_fields=("at_iter",),
                          meta_fields=("kind", "site", "persistent"))
