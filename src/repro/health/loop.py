"""The health-instrumented outer-loop driver shared by every solver.

Extends the tolerance-aware ``lax.while_loop`` driver (api/driver.py is
now a thin wrapper over this module) with:

* **detection** — after every step the new iterate is checked for
  non-finite leaves *and* mass collapse (total ℓ1 below ``mass_floor``,
  the silent failure mode of underflowed plain-domain kernels at tiny ε)
  *and* mass explosion (ℓ1 above ``mass_ceil`` — an overflow in progress
  that log-domain inner solves would otherwise carry, finite, to the
  final iterate); an unhealthy iterate is never kept — the lane holds
  its last healthy state;
* **ε-rescue** — an unhealthy step consumes one of ``max_rescues``
  restarts: the lane resumes from its last healthy iterate and the step
  escalation ``scale`` doubles (``rescue_factor ** n_rescues``), which
  solvers map onto their own stability knob (ε-doubling for entropic
  kernels, step-size halving for mirror descent). Rescues draw no new
  randomness, so a recovered solve is bitwise reproducible. When rescue
  is exhausted the lane dies with status DIVERGED at the iteration of
  first failure;
* **status** — the loop returns a :class:`~repro.health.status.
  SolveStatus` computed per lane: DIVERGED > STALLED (tolerance met but
  marginal error above ``stall_err`` — a non-coupling fixed point) >
  MAXITER > CONVERGED;
* **fault injection** — an optional :class:`~repro.health.faults.
  FaultSpec` poisons the iterate at configured iterations, making all of
  the above testable (site="cost" poisons the step *input*, so the fault
  transits the cost evaluation and inner Sinkhorn).

Everything is masked per lane with the same ``where(done, old, new)``
trick as before, so the loop keeps its ``jit``/``vmap`` contract: one
poisoned lane in a stacked solve neither corrupts nor delays its peers.
With ``max_rescues=0``, no fault, and a healthy trajectory the numerics
are bitwise-identical to the pre-health driver (the guards only ever
*read* the iterate).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.health.status import (
    CONVERGED,
    DIVERGED,
    MAXITER,
    STALLED,
    SolveStatus,
)
from repro.obs.trace import ConvergenceTrace, empty_trace

_TINY = 1e-30

# iterates with total ℓ1 mass below this are "collapsed": every entry of
# a coupling underflowed to zero (e.g. K = exp(-C/ε) at tiny ε in the
# plain domain) — finite, but as fatal as a NaN
DEFAULT_MASS_FLOOR = 1e-20

# ...and above this they are "exploded": a coupling's mass is bounded by
# its marginals (O(1)), so an iterate at 1e20 is an overflow in progress
# that hasn't hit inf yet (log-domain inner solves can renormalize every
# *subsequent* step while the scaled iterate itself survives to the end)
DEFAULT_MASS_CEIL = 1e20

# a tolerance-met lane whose final marginal ℓ1 violation exceeds this is
# STALLED, not CONVERGED: the historical dense-PGA mixing fixed points
# left 0.3–1.0 of violation, healthy converged solves reach ≲1e-2
DEFAULT_STALL_ERR = 0.25


class LoopResult(NamedTuple):
    """What the driver hands back to a solver."""
    iterate: Any        # last healthy iterate (pytree)
    errors: Any         # (max_iters,) per-iteration diagnostic, NaN-padded
    n_iters: Any        # iterations consumed (including rescue attempts)
    converged: Any      # tolerance met (bool; False under tol=0)
    status: SolveStatus
    trace: Optional[ConvergenceTrace] = None   # per-iteration buffers
                                               # (None unless trace=True)


def _tree_l1(tree):
    return jax.tree.reduce(
        lambda acc, leaf: acc + jnp.sum(jnp.abs(leaf)), tree, jnp.float32(0))


def tree_finite(tree):
    """Scalar bool: every leaf of ``tree`` is everywhere finite."""
    return jax.tree.reduce(
        lambda acc, leaf: acc & jnp.all(jnp.isfinite(leaf)), tree,
        jnp.bool_(True))


def health_loop(step_fn: Callable, err_fn: Callable, T0, max_iters: int,
                tol: float, *, scaled_step: bool = False,
                max_rescues: int = 0, rescue_factor: float = 2.0,
                mass_floor: float = DEFAULT_MASS_FLOOR,
                mass_ceil: float = DEFAULT_MASS_CEIL,
                stall_err: float = DEFAULT_STALL_ERR,
                fault: Optional[Any] = None,
                trace: bool = False,
                obj_fn: Optional[Callable] = None) -> LoopResult:
    """Iterate ``T <- step_fn(T[, scale])`` with health instrumentation.

    step_fn     — one outer solver step; with ``scaled_step`` it receives
                  ``(T, scale)`` where ``scale = rescue_factor**n_rescues``
                  is the rescue escalation (1.0 until a rescue fires)
    err_fn      — per-iteration diagnostic (marginal ℓ1 violation)
    tol         — stop when the relative ℓ1 change of the iterate (summed
                  over pytree leaves) is <= tol; 0 compiles the predicate
                  out (fixed budget, ``converged`` stays False)
    max_rescues — divergence restarts before a lane dies DIVERGED
    fault       — optional FaultSpec (see health/faults.py)
    trace       — static: carry :class:`~repro.obs.trace.ConvergenceTrace`
                  buffers through the loop and return them on the result;
                  when False (default) the loop body is the exact pre-obs
                  computation and ``result.trace`` is None (zero leaves)
    obj_fn      — optional per-iteration objective ``obj_fn(T_new) ->
                  scalar``, recorded in the trace; only evaluated when
                  ``trace=True`` (otherwise ignored)

    All keyword arguments except ``fault.at_iter`` are static.
    """
    errs0 = jnp.full((max_iters,), jnp.nan, jnp.float32)
    if max_iters <= 0:
        return LoopResult(T0, errs0, jnp.int32(0), jnp.bool_(False),
                          SolveStatus.healthy(MAXITER),
                          empty_trace(0) if trace else None)

    def cond(state):
        # indexed (not star-unpacked): the trace buffers, when carried,
        # ride at the end of the state tuple
        i, conv, dead = state[0], state[6], state[7]
        return (i < max_iters) & jnp.logical_not(conv | dead)

    def body(state):
        if trace:
            i, T, errs, last_err, fail_iter, n_rescues, conv, dead, tr = state
        else:
            i, T, errs, last_err, fail_iter, n_rescues, conv, dead = state
        done = conv | dead
        T_in = fault.apply(T, i) if fault is not None and \
            fault.site == "cost" else T
        if scaled_step:
            scale = jnp.float32(rescue_factor) ** n_rescues
            T_new = step_fn(T_in, scale)
        else:
            T_new = step_fn(T_in)
        if fault is not None and fault.site == "iterate":
            T_new = fault.apply(T_new, i)
        l1 = _tree_l1(T_new)
        healthy = tree_finite(T_new) & (l1 > mass_floor) & (l1 < mass_ceil)
        bad = jnp.logical_not(healthy) & jnp.logical_not(done)
        # an unhealthy step consumes a rescue (restart from the current,
        # still-healthy T with escalated scale) or kills the lane
        can_rescue = n_rescues < max_rescues
        fail_iter = jnp.where(bad & (fail_iter < 0), i, fail_iter)
        rescued_now = bad & can_rescue
        n_rescues_in = n_rescues          # pre-update: the scale in effect
        n_rescues = jnp.where(rescued_now, n_rescues + 1, n_rescues)
        dead = dead | (bad & jnp.logical_not(can_rescue))
        # only healthy, not-yet-done lanes advance their iterate/diagnostics
        adv = healthy & jnp.logical_not(done)
        err = err_fn(T_new).astype(jnp.float32)
        errs = jnp.where(adv, errs.at[i].set(err), errs)
        last_err = jnp.where(adv, err, last_err)
        T_out = jax.tree.map(lambda new, old: jnp.where(adv, new, old),
                             T_new, T)
        i_out = jnp.where(done, i, i + 1)   # rescues consume budget too
        delta = None
        if trace or tol > 0:
            num = _tree_l1(jax.tree.map(lambda new, old: new - old, T_new, T))
            delta = num / jnp.maximum(_tree_l1(T), _TINY)
        if tol > 0:                  # tol is static: predicate compiled out
            conv = conv | (adv & (delta <= tol))
        if trace:
            notdone = jnp.logical_not(done)

            def _wr(buf, val, mask):
                return jnp.where(mask,
                                 buf.at[i].set(val.astype(jnp.float32)), buf)

            # err/objective/delta describe an *accepted* step (mask adv);
            # mass/scale/rescued describe the attempt itself (mask ~done),
            # so rescue iterations keep their forensic record: the
            # exploded mass, the scale that failed, the rescue event
            obj = (obj_fn(T_new).astype(jnp.float32)
                   if obj_fn is not None else None)
            scale_now = jnp.float32(rescue_factor) ** n_rescues_in
            tr = ConvergenceTrace(
                err=_wr(tr.err, err, adv),
                objective=(_wr(tr.objective, obj, adv)
                           if obj is not None else tr.objective),
                delta=_wr(tr.delta, delta, adv),
                mass=_wr(tr.mass, l1, notdone),
                scale=_wr(tr.scale, scale_now, notdone),
                rescued=_wr(tr.rescued,
                            jnp.where(rescued_now, jnp.float32(1),
                                      jnp.float32(0)), notdone),
            )
            return (i_out, T_out, errs, last_err, fail_iter, n_rescues,
                    conv, dead, tr)
        return i_out, T_out, errs, last_err, fail_iter, n_rescues, conv, dead

    state0 = (jnp.int32(0), T0, errs0, jnp.float32(jnp.nan), jnp.int32(-1),
              jnp.int32(0), jnp.bool_(False), jnp.bool_(False))
    if trace:
        state0 = state0 + (empty_trace(max_iters),)
    final = lax.while_loop(cond, body, state0)
    (n_iters, T, errors, last_err, fail_iter, n_rescues, conv,
     dead) = final[:8]
    tr_out = final[8] if trace else None

    stalled = conv & (last_err > stall_err)
    code = jnp.where(dead, DIVERGED,
                     jnp.where(stalled, STALLED,
                               jnp.where(conv, CONVERGED,
                                         MAXITER))).astype(jnp.int32)
    status = SolveStatus(code=code, fail_iter=fail_iter, last_err=last_err,
                         n_rescues=n_rescues)
    return LoopResult(T, errors, n_iters, conv, status, tr_out)
