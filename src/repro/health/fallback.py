"""Solver fallback ladder — what ``solve(..., on_failure="fallback")`` walks.

When a solve comes back unhealthy (DIVERGED/STALLED status or non-finite
value) after its own in-jit ε-rescue budget is exhausted, the front door
retries the problem on the next solver down the ladder

    lowrank_gw -> quantized_gw -> spar_gw -> dense_gw

ordered most-scalable-first and gated by the same structural eligibility
rules as ``select_solver`` (lowrank needs balanced/non-fused/decomposable
problems) plus feasibility caps (spar's O((16n)²) assembly and dense's
O(n³)-per-iteration work stop being answers at large n). Each attempt is
re-keyed deterministically — ``jax.random.fold_in(key, attempt)`` — so a
recovered solve is bitwise reproducible run-to-run.
"""
from __future__ import annotations

from typing import Sequence

# most scalable first; grid_gw is excluded (it is a sparsification
# *variant*, not a robustness rung — same failure surface as spar_gw)
LADDER = ("lowrank_gw", "quantized_gw", "spar_gw", "dense_gw")

# feasibility caps on max(m, n) for the quadratic/cubic rungs: 4× the
# auto-selection thresholds — a fallback may pay more than the router
# would choose, but not an infeasible amount
FALLBACK_SPAR_MAX = 8192
FALLBACK_DENSE_MAX = 1024


def fallback_chain(problem, exclude: Sequence[str] = (),
                   key_available: bool = True):
    """Ordered list of solver configs eligible to retry ``problem``.

    exclude       — registry names already tried (the primary solver and
                    any spent fallback attempts)
    key_available — False drops solvers that require a PRNG key (the
                    ladder then typically reduces to dense_gw)
    """
    # late imports: api.solve imports this module at call time
    from repro.api.solve import _lowrank_eligible
    from repro.api.solvers import get_solver

    size = max(problem.shape)
    fused_unbalanced = problem.is_fused and problem.is_unbalanced
    chain = []
    for name in LADDER:
        if name in exclude:
            continue
        if name == "lowrank_gw" and not _lowrank_eligible(problem):
            continue
        if name == "spar_gw" and (size > FALLBACK_SPAR_MAX
                                  or fused_unbalanced):
            continue
        if name == "dense_gw" and (size > FALLBACK_DENSE_MAX
                                   or fused_unbalanced):
            continue
        cls = get_solver(name)
        if not key_available and getattr(cls, "requires_key", False):
            continue
        chain.append(cls.default_config(size))
    return chain
