"""Numerical health & recovery layer (DESIGN.md §8).

Detection (finite-guards + stall classification in the shared loop
driver), ε-rescue restarts, the solver fallback ladder behind
``solve(..., on_failure="fallback")``, and the fault-injection chaos
harness that makes all of it testable.
"""
from repro.health.faults import FaultSpec
from repro.health.fallback import LADDER, fallback_chain
from repro.health.loop import (
    DEFAULT_MASS_CEIL,
    DEFAULT_MASS_FLOOR,
    DEFAULT_STALL_ERR,
    LoopResult,
    health_loop,
    tree_finite,
)
from repro.health.status import (
    CONVERGED,
    DIVERGED,
    MAXITER,
    STALLED,
    STATUS_NAMES,
    SolveDivergedError,
    SolveStatus,
)

__all__ = [
    "CONVERGED",
    "MAXITER",
    "STALLED",
    "DIVERGED",
    "STATUS_NAMES",
    "SolveStatus",
    "SolveDivergedError",
    "FaultSpec",
    "LoopResult",
    "health_loop",
    "tree_finite",
    "DEFAULT_MASS_CEIL",
    "DEFAULT_MASS_FLOOR",
    "DEFAULT_STALL_ERR",
    "fallback_chain",
    "LADDER",
]
