"""Solve-status lattice — the machine-readable health verdict of a solve.

Four codes, ordered by severity (higher = worse), chosen so that lattice
joins are ``jnp.maximum``:

    CONVERGED (0) — outer tolerance met, marginal error healthy
    MAXITER   (1) — iteration budget exhausted before the tolerance
    STALLED   (2) — the iterate reached a fixed point (tolerance met) but
                    the marginal violation stayed large: a non-coupling
                    fixed point (the dense-PGA mixing stalls of PR 4)
    DIVERGED  (3) — a non-finite or mass-collapsed iterate appeared and
                    rescue (if enabled) was exhausted; the returned state
                    is the last *healthy* iterate, never the poisoned one

``SolveStatus`` is a NamedTuple of arrays, so it is a pytree: a
``vmap``-batched solve returns one status whose leaves carry the batch
dimension, and per-lane verdicts stay independent (one poisoned lane in a
stack reports DIVERGED while its peers report their own codes).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp

CONVERGED = 0
MAXITER = 1
STALLED = 2
DIVERGED = 3

STATUS_NAMES = ("CONVERGED", "MAXITER", "STALLED", "DIVERGED")


class SolveStatus(NamedTuple):
    """Per-solve (per-lane under vmap) numerical-health verdict.

    code      — int32 lattice code (see module constants)
    fail_iter — iteration index of the *first* unhealthy step (non-finite
                or mass-collapsed), whether or not it was later rescued;
                -1 if the solve never went unhealthy
    last_err  — last finite recorded diagnostic (marginal ℓ1 violation);
                NaN if no iteration completed healthily
    n_rescues — ε-rescue restarts consumed (0 = none needed)
    """
    code: Any
    fail_iter: Any
    last_err: Any
    n_rescues: Any

    @property
    def is_converged(self):
        return self.code == CONVERGED

    @property
    def is_stalled(self):
        return self.code == STALLED

    @property
    def is_diverged(self):
        return self.code == DIVERGED

    @property
    def is_healthy(self):
        """CONVERGED or MAXITER — the solve produced a usable iterate."""
        return self.code <= MAXITER

    @classmethod
    def healthy(cls, code):
        """An all-clear status with the given code (no failure recorded)."""
        return cls(code=jnp.int32(code), fail_iter=jnp.int32(-1),
                   last_err=jnp.float32(jnp.nan), n_rescues=jnp.int32(0))

    def join(self, other: "SolveStatus") -> "SolveStatus":
        """Lattice join of two stage statuses (e.g. coarse solve + polish):
        the worse code wins and carries its failure provenance."""
        worse = other.code > self.code
        pick = lambda x, y: jnp.where(worse, y, x)  # noqa: E731
        return SolveStatus(jnp.maximum(self.code, other.code),
                           pick(self.fail_iter, other.fail_iter),
                           pick(self.last_err, other.last_err),
                           self.n_rescues + other.n_rescues)

    def describe(self):
        """Human-readable code name(s) — host-side helper, not jittable.
        Returns a str for a scalar status, a list of str for a batch."""
        import numpy as np
        code = np.asarray(self.code)
        if code.ndim == 0:
            return STATUS_NAMES[int(code)]
        return [STATUS_NAMES[int(c)] for c in code.reshape(-1)]


class SolveDivergedError(RuntimeError):
    """Raised by ``solve(..., on_failure="raise")`` when the solve failed
    (DIVERGED/STALLED status or non-finite value) and, under
    ``on_failure="fallback"``, when every ladder candidate failed too."""

    def __init__(self, message: str, output=None):
        super().__init__(message)
        self.output = output
