"""Low-rank GW solver subsystem (DESIGN.md §7).

Couplings factored as ``T = Q diag(1/g) Rᵀ`` and costs as skinny
``U Vᵀ`` products, making every GW iteration linear in m + n (Scetbon,
Peyré & Cuturi, 2021/22). Importing this package registers the
``lowrank_gw`` solver.
"""
from repro.lowrank.dykstra import lr_dykstra
from repro.lowrank.factorize import (
    CostFactors,
    GroundFactors,
    factor_ground,
    khatri_rao_square,
    sketch_factors,
    sq_euclidean_factors,
)
from repro.lowrank.gradients import gw_lr_gradients, gw_lr_value
from repro.lowrank.solver import LowRankGWSolver

__all__ = [
    "CostFactors",
    "GroundFactors",
    "LowRankGWSolver",
    "factor_ground",
    "gw_lr_gradients",
    "gw_lr_value",
    "khatri_rao_square",
    "lr_dykstra",
    "sketch_factors",
    "sq_euclidean_factors",
]
