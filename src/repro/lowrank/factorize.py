"""Low-rank cost factorization — the C ≈ U Vᵀ contract (DESIGN.md §7).

Every per-iteration quantity of the low-rank GW solver touches the n×n
cost matrices only through matvecs, so all a geometry has to provide is a
pair of skinny factors. Two producers:

* **exact** — a point-cloud geometry's squared euclidean distance matrix
  factors at rank d+2 with no error (Scetbon et al., 2021):
  ``D²_ij = ||x_i||² + ||x_j||² - 2 x_i·x_j`` is
  ``[z | 1 | -2X] [1 | z | X]ᵀ`` with ``z = ||x_i||²``;
* **sketch** — an arbitrary precomputed cost matrix gets a randomized
  rank-c range sketch (Halko et al.): ``U = qr(C Ω)``, ``V = Cᵀ U``, one
  O(n²·c) pass at setup, never again per iteration.

``factor_ground`` wraps both behind the ground-loss decomposition
``L(x, y) = f1(x) + f2(y) - h1(x) h2(y)``: it returns factors of h(C)
(the only matrix the GW gradient applies) plus an ``apply_f`` closure for
the rank-one f-terms of the final objective. Elementwise maps of a
factored matrix (f1 = square for the l2 loss) stay factored through the
Khatri-Rao identity ``(UVᵀ) ∘ (UVᵀ) = (U ⊙ U)(V ⊙ V)ᵀ`` at rank (d+2)².
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ground_cost as gc


class CostFactors(NamedTuple):
    """Skinny factors ``U (n×c), V (n×c)`` of a symmetric matrix ≈ U Vᵀ."""
    u: Any
    v: Any

    @property
    def rank(self) -> int:
        return self.u.shape[1]

    def apply(self, x):
        """(U Vᵀ) @ x in O(n·c) — vector or (n, k) stack."""
        return self.u @ (self.v.T @ x)

    def todense(self):
        return self.u @ self.v.T

    def scale(self, s: float) -> "CostFactors":
        return CostFactors(self.u, s * self.v)


def sq_euclidean_factors(points) -> CostFactors:
    """Exact rank-(d+2) factors of the squared euclidean distance matrix."""
    z = jnp.sum(points * points, axis=1, keepdims=True)     # (n, 1)
    one = jnp.ones_like(z)
    U = jnp.concatenate([z, one, -2.0 * points], axis=1)    # (n, d+2)
    V = jnp.concatenate([one, z, points], axis=1)           # (n, d+2)
    return CostFactors(U, V)


def khatri_rao_square(f: CostFactors) -> CostFactors:
    """Factors of the *elementwise square* of a factored matrix.

    (U Vᵀ)∘(U Vᵀ) = KR(U, U) KR(V, V)ᵀ at rank c², where KR pairs every
    column with every column — O(n·c²) storage, exact.
    """
    n, c = f.u.shape
    kr = lambda A: (A[:, :, None] * A[:, None, :]).reshape(n, c * c)
    return CostFactors(kr(f.u), kr(f.v))


def sketch_factors(C, rank: int, key, power_iters: int = 1) -> CostFactors:
    """Randomized range sketch C ≈ U (Uᵀ C) with U = qr((C Cᵀ)^p C Ω).

    One-time O(n²·c) setup cost; ``power_iters`` sharpens the spectrum of
    slowly-decaying distance matrices (Halko et al. recommend 1-2).
    """
    n = C.shape[0]
    omega = jax.random.normal(key, (n, rank), C.dtype)
    Y = C @ omega
    for _ in range(power_iters):
        Y, _ = jnp.linalg.qr(Y)
        Y = C @ (C.T @ Y)
    U, _ = jnp.linalg.qr(Y)                                 # (n, rank)
    return CostFactors(U, C.T @ U)


class GroundFactors(NamedTuple):
    """One geometry's low-rank view of a decomposable ground loss.

    h        — factors of h(C): the matrix the quadratic gradient applies
               every iteration, O(n·c) per matvec
    apply_f  — x ↦ f(C) @ x for the objective's rank-one terms (factored
               on the exact path, a dense matvec on the sketch path)
    exact    — True on the point-cloud rank-(d+2) path
    """
    h: CostFactors
    apply_f: Callable
    exact: bool


def factor_ground(geom, loss: str, side: str, cost_rank: int,
                  key) -> GroundFactors:
    """Factor one side's h-matrix (h1(Cx) or h2(Cy)) + f-term applier.

    Point-cloud geometries with the l2 loss take the exact path: h is
    linear in C there (h1 = id, h2 = 2·id), so the rank-(d+2) distance
    factors serve directly, and f (= square) stays factored through the
    Khatri-Rao square. Everything else materializes ``geom.cost_matrix``
    once and sketches h(C) at rank ``cost_rank``.
    """
    dec = gc.get_decomposition(loss)
    if dec is None:
        raise NotImplementedError(
            f"lowrank_gw needs a decomposable ground loss "
            f"L = f1 + f2 - h1·h2; {loss!r} has no decomposition "
            f"(known decomposable: l2, kl)")
    h_fn = dec.h1 if side == "x" else dec.h2
    f_fn = dec.f1 if side == "x" else dec.f2

    if geom.is_point_cloud and geom.cost is None and loss == "l2":
        base = sq_euclidean_factors(geom.points)
        h = base if side == "x" else base.scale(2.0)        # h2 = 2y
        fsq = khatri_rao_square(base)                       # f = y², exact
        return GroundFactors(h=h, apply_f=fsq.apply, exact=True)

    C = geom.cost_matrix
    H = h_fn(C)
    F = f_fn(C)
    return GroundFactors(h=sketch_factors(H, cost_rank, key),
                         apply_f=lambda x: F @ x, exact=False)
