"""Low-rank GW gradients and objective — never an m×n intermediate.

With the coupling factored as ``T = Q diag(1/g) Rᵀ`` and the ground-loss
h-matrices factored as ``Hx ≈ U1 V1ᵀ``, ``Hy ≈ U2 V2ᵀ``, the quadratic
part of the GW objective restricted to the coupling polytope is

    F(Q, R, g) = -⟨Hx T Hy, T⟩ = -tr(Sx D Sy D),
    Sx = Qᵀ Hx Q,  Sy = Rᵀ Hy R,  D = diag(1/g)

(the f1/f2 terms are constant on the polytope and re-enter only in the
reported value). Every factor of every product is skinny, so gradients
cost O((m + n)·r·(r + c)) — linear in m + n. The mirror-descent kernels
in solver.py exponentiate exactly these gradients.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.lowrank.factorize import CostFactors, GroundFactors


class LRGradients(NamedTuple):
    grad_q: jnp.ndarray   # (m, r) = ∂F/∂Q = G R diag(1/g), G = -2 Hx T Hy
    grad_r: jnp.ndarray   # (n, r) = ∂F/∂R = Gᵀ Q diag(1/g)
    grad_g: jnp.ndarray   # (r,)  = ∂F/∂g = -diag(Qᵀ G R)/g²


def _small_gram(h: CostFactors, X):
    """Sx = Xᵀ (U Vᵀ) X as two skinny products, (r × r)."""
    return (h.u.T @ X).T @ (h.v.T @ X)


def gw_lr_gradients(Q, R, g, hx: CostFactors, hy: CostFactors):
    """Gradients of F(Q, R, g) = -⟨Hx T Hy, T⟩ at T = Q diag(1/g) Rᵀ."""
    inv_g = 1.0 / g
    v1q = hx.v.T @ Q                       # (c1, r)
    u2r = hy.u.T @ R                       # (c2, r)
    v2r = hy.v.T @ R                       # (c2, r)
    u1q = hx.u.T @ Q                       # (c1, r)
    sx = u1q.T @ v1q                       # Qᵀ Hx Q   (r, r)
    sy = u2r.T @ v2r                       # Rᵀ Hy R   (r, r)
    # G R D = -2 Hx Q D (Rᵀ Hy R) D  — assembled right-to-left, all skinny
    grad_q = -2.0 * (hx.u @ ((v1q * inv_g[None, :]) @ sy * inv_g[None, :]))
    # Gᵀ Q D = -2 Hy R D (Qᵀ Hx Q) D
    grad_r = -2.0 * (hy.u @ ((v2r * inv_g[None, :]) @ sx * inv_g[None, :]))
    # ∂F/∂g_k = (2/g_k²) Σ_l Sx[k, l] (1/g_l) Sy[l, k]
    grad_g = 2.0 * jnp.einsum("kl,lk->k", sx, sy * inv_g[:, None]) * inv_g**2
    return LRGradients(grad_q, grad_r, grad_g)


def gw_lr_value(Q, R, g, fx: GroundFactors, fy: GroundFactors):
    """Plug-in GW objective of the factored coupling, O((m + n)·(r + c)²).

    value = ⟨f1(Cx) μ, μ⟩ + ⟨f2(Cy) ν, ν⟩ - ⟨Hx T Hy, T⟩ with (μ, ν) the
    actual marginals of T = Q diag(1/g) Rᵀ (μ = Q (Rᵀ1/g), matching
    ``LowRankCoupling.marginals`` — not the factor row sums, which
    differ by any residual inner-marginal violation) — mirrors
    ``gw_objective``'s plug-in convention on the other solver families.
    """
    mu = Q @ (R.sum(axis=0) / g)
    nu = R @ (Q.sum(axis=0) / g)
    inv_g = 1.0 / g
    sx = _small_gram(fx.h, Q)
    sy = _small_gram(fy.h, R)
    cross = jnp.einsum("kl,lk->", sx * inv_g[None, :], sy * inv_g[None, :])
    return (jnp.dot(mu, fx.apply_f(mu)) + jnp.dot(nu, fy.apply_f(nu))
            - cross)
