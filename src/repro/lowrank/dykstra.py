"""LR-Dykstra — projection of low-rank factors onto the coupling polytope.

One mirror-descent step of the low-rank GW solver produces three positive
kernels ``(K1, K2, k3)``; this module projects them onto

    C(a, b, r) = {(Q, R, g): Q 1_r = a, R 1_r = b,
                  Qᵀ1_m = Rᵀ1_n = g, g ≥ α}

in KL geometry via Dykstra's alternating projections (Scetbon, Cuturi &
Peyré, 2021, Alg. 2). Each iteration is a handful of (m×r)/(n×r) matvecs
— O((m + n)·r), the bound that makes every outer GW iteration linear in
n. The loop runs through the shared ``_scaling_loop`` driver, so it
inherits the fixed-budget / tolerance-aware / vmap-safe semantics of
every other inner projection in the codebase.

The ``α`` lower bound on the inner marginal ``g`` is not cosmetic: rank
collapse (g_k → 0) divides by zero in ``T = Q diag(1/g) Rᵀ`` and stalls
the mirror descent; flooring g keeps all r components live.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.sinkhorn import _scaling_loop
from repro.core.utils import safe_div


def _or(x, fallback):
    """x where finite, else fallback — extreme kernels (e^{±1/ε} at tiny
    ε) drive 0·inf / inf/inf products non-finite; dropping that update is
    the KL-safe fallback and the layer's never-silent-NaN contract."""
    return jnp.where(jnp.isfinite(x), x, fallback)


def lr_dykstra(K1, K2, k3, a, b, alpha: float, iters: int, tol: float):
    """Project kernels (K1 ∈ ℝ^{m×r}, K2 ∈ ℝ^{n×r}, k3 ∈ ℝ^r) onto
    C(a, b, r). Returns the feasible factors ``(Q, R, g)``.

    ``tol=0`` runs the fixed budget; ``tol>0`` stops once the sup-norm
    change of all scalings drops below tol (vmap-safe lane freezing).
    """
    r = k3.shape[0]
    m, n = K1.shape[0], K2.shape[0]
    ones_r = jnp.ones((r,), K1.dtype)
    # (u1, u2) row scalings, (v1, v2) column scalings, g inner marginal,
    # (q1, q2, q3_1, q3_2) Dykstra correction terms
    init = (jnp.ones((m,), K1.dtype), jnp.ones((n,), K2.dtype),
            ones_r, ones_r, k3, ones_r, ones_r, ones_r, ones_r)

    def body(carry):
        u1, u2, v1, v2, g, q1, q2, q3_1, q3_2 = carry
        # outer-marginal projections: Q 1_r = a, R 1_r = b
        u1 = safe_div(a, K1 @ v1)
        u2 = safe_div(b, K2 @ v2)
        # g ≥ α projection (with its Dykstra correction)
        g_mid = jnp.maximum(alpha, _or(g * q3_1, g))
        q3_1 = _or(safe_div(g * q3_1, g_mid), 1.0)
        # shared inner marginal: Qᵀ1 = Rᵀ1 = g, geometric-mean coupling
        kt1u = K1.T @ u1
        kt2u = K2.T @ u2
        prod1 = (v1 * q1) * kt1u
        prod2 = (v2 * q2) * kt2u
        g_raw = (g_mid * q3_2 * prod1 * prod2) ** (1.0 / 3.0)
        g_new = jnp.where(jnp.isfinite(g_raw) & (g_raw > 0), g_raw, g_mid)
        v1_new = safe_div(g_new, kt1u)
        v2_new = safe_div(g_new, kt2u)
        q1 = _or(safe_div(v1 * q1, v1_new), 1.0)
        q2 = _or(safe_div(v2 * q2, v2_new), 1.0)
        q3_2 = _or(safe_div(g_mid * q3_2, g_new), 1.0)
        return (u1, u2, v1_new, v2_new, g_new, q1, q2, q3_1, q3_2)

    u1, u2, v1, v2, g, *_ = _scaling_loop(body, init, iters, tol)
    Q = _or(u1[:, None] * K1 * v1[None, :], 0.0)
    R = _or(u2[:, None] * K2 * v2[None, :], 0.0)
    return Q, R, g
