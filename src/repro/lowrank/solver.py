"""``LowRankGWSolver`` — linear-time GW with rank-r couplings.

Scetbon, Peyré & Cuturi's GW-LR on the unified API: the coupling is kept
factored as ``T = Q diag(1/g) Rᵀ`` throughout, the ground costs enter
only through skinny factors (exact rank d+2 for point-cloud geometries,
randomized rank-c sketches otherwise — factorize.py), and each outer step
is mirror descent on (Q, R, g) followed by a LR-Dykstra projection onto
the coupling polytope (dykstra.py). Per-iteration cost is
O((m + n)·r·(r + c)): the first solver family in the registry whose
per-iteration cost is *linear* in m + n — the n ≥ 10⁵ regime opener.

The config is a pytree with ``epsilon`` (entropic smoothing of the mirror
step) and ``gamma`` (mirror step size) as dynamic leaves, so sweeps over
either never retrace. The outer loop runs through the shared
tolerance-aware ``pga_loop`` driver with the (Q, R, g) triple as its
pytree iterate; jit+vmap composition comes for free like every other
solver.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.api.driver import pga_loop
from repro.api.output import GWOutput, LowRankCoupling
from repro.api.pytree import register_pytree_dataclass
from repro.api.solvers import _require_key, register_solver
from repro.lowrank.dykstra import lr_dykstra
from repro.lowrank.factorize import factor_ground
from repro.lowrank.gradients import gw_lr_gradients, gw_lr_value
from repro.lowrank.init import anchor_init, random_init

# floor for log(max(·, _TINY)) kernels: must be a *normal* float32 — XLA
# CPU flushes subnormals, so 1e-38 would give log(0) = -inf and
# 0 · (-inf) = NaN when the entropic exponent clamps to 0 (same defect
# class as multiscale's _PAD_WEIGHT)
_TINY = 1e-30


def _auto_rank(m: int, n: int) -> int:
    """Constant-by-default coupling rank (the paper's r ∈ [10, 100] regime
    with small-problem clamping) — keeps per-iteration cost linear."""
    return max(2, min(min(m, n) // 2, 10))


def _auto_cost_rank(m: int, n: int) -> int:
    # saturates (exact) below 32 points — small nested/coarse problems
    # shouldn't pay sketch error for a matrix already tiny
    return min(min(m, n), 32)


# the historical random init now lives in lowrank/init.py (random_init)
# alongside the FPS/anchor-seeded structured init (anchor_init)
_init_factors = random_init


@dataclass(frozen=True)
class LowRankGWSolver:
    """Low-rank GW (Scetbon et al.) — balanced, decomposable losses.

    rank          — coupling rank r (0 → auto: min(n/2, 10))
    cost_rank     — sketch rank c for non-point-cloud geometries
                    (0 → auto: min(n, 32), i.e. exact below 32 points);
                    ignored on the exact rank-(d+2) point-cloud path
    epsilon       — entropic smoothing of the mirror step (dynamic leaf;
                    0 = pure mirror descent, the paper's default)
    gamma         — mirror-descent step size (dynamic leaf); rescaled per
                    step by the sup-norm of the gradients when
                    ``gamma_rescale`` (the paper's adaptive choice, keeps
                    the kernel exponents bounded by ±gamma)
    g_floor       — lower bound α on the inner marginal g (rank-collapse
                    guard inside Dykstra)
    init          — factor initialization: "anchors" (default — FPS
                    anchor compression + r×r anchor GW, lifted to
                    feasible factors; lowrank/init.py) or "random" (the
                    historical symmetric-broken random init)
    init_blend    — uniform-coupling fraction τ mixed into the anchors
                    init (keeps every factor entry positive)
    outer_iters   — mirror-descent step budget
    inner_iters   — Dykstra budget per mirror step
    tol           — outer stop: relative ℓ1 change of (Q, R, g)
    inner_tol     — Dykstra stop: sup-norm change of the scalings
    max_rescues, rescue_factor — driver ε-rescue budget on detected
                    divergence; for mirror descent the escalation
                    *divides γ* (step-size halving) rather than scaling
                    ε — an overflowing MD kernel is tamed by a smaller
                    step, and ε may legitimately be 0 here
    fault         — chaos-testing hook (health/faults.py)
    trace         — record per-iteration convergence buffers (err, GW-LR
                    objective, step scale, rescues) onto ``output.trace``
    """
    rank: int = 0
    cost_rank: int = 0
    epsilon: Any = 0.0
    gamma: Any = 10.0
    gamma_rescale: bool = True
    g_floor: float = 1e-10
    init: str = "anchors"
    init_blend: float = 0.2
    outer_iters: int = 300
    inner_iters: int = 200
    tol: float = 1e-6
    inner_tol: float = 3e-6
    max_rescues: int = 2
    rescue_factor: float = 2.0
    fault: Any = None
    trace: bool = False

    requires_key = True

    @classmethod
    def default_config(cls, n: int):
        return cls()

    def _resolve(self, m: int, n: int):
        rank = self.rank or _auto_rank(m, n)
        cost_rank = self.cost_rank or _auto_cost_rank(m, n)
        return min(rank, min(m, n)), min(cost_rank, min(m, n))

    def run(self, problem, key=None) -> GWOutput:
        if problem.is_fused or problem.is_unbalanced:
            raise NotImplementedError(
                "LowRankGWSolver supports balanced non-fused problems only; "
                "use SparGWSolver / QuantizedGWSolver for fused/unbalanced "
                "variants")
        _require_key(key, "LowRankGWSolver")
        a = problem.geom_x.weights
        b = problem.geom_y.weights
        m, n = problem.shape
        rank, cost_rank = self._resolve(m, n)
        key_init, key_fx, key_fy = jax.random.split(key, 3)

        fx = factor_ground(problem.geom_x, problem.loss, "x", cost_rank,
                           key_fx)
        fy = factor_ground(problem.geom_y, problem.loss, "y", cost_rank,
                           key_fy)
        if self.init == "anchors":
            state0 = anchor_init(key_init, problem, rank,
                                 blend=self.init_blend)
        elif self.init == "random":
            state0 = random_init(key_init, a, b, rank)
        else:
            raise ValueError(f"unknown init {self.init!r} "
                             f"(known: anchors, random)")

        step = partial(self._md_step, a=a, b=b, hx=fx.h, hy=fy.h)

        def err_fn(state):
            # ℓ1 marginal violation of the *coupling* T = Q diag(1/g) Rᵀ
            # (same contract as LowRankCoupling.marginals)
            Q, R, g = state
            mu = Q @ (R.sum(axis=0) / g)
            nu = R @ (Q.sum(axis=0) / g)
            return jnp.sum(jnp.abs(mu - a)) + jnp.sum(jnp.abs(nu - b))
        def obj_fn(state):
            return gw_lr_value(state[0], state[1], state[2], fx, fy)

        (Q, R, g), errors, n_iters, converged, status, trace = pga_loop(
            step, err_fn, state0, self.outer_iters, self.tol,
            scaled_step=True, max_rescues=self.max_rescues,
            rescue_factor=self.rescue_factor, fault=self.fault,
            trace=self.trace, obj_fn=obj_fn)

        value = gw_lr_value(Q, R, g, fx, fy)
        return GWOutput(value=value, coupling=LowRankCoupling(Q, R, g),
                        errors=errors, converged=converged, n_iters=n_iters,
                        status=status, trace=trace)

    def _md_step(self, state, scale, a, b, hx, hy):
        """One mirror-descent + Dykstra-projection step on (Q, R, g).

        ``scale`` is the driver's rescue escalation: it shrinks the
        mirror step (γ / scale), the MD analogue of ε-doubling.
        """
        Q, R, g = state
        grads = gw_lr_gradients(Q, R, g, hx, hy)
        # Project out gradient components the constraint set absorbs: a
        # row-constant of ∇Q/∇R only rescales a row of the kernel, which
        # Dykstra's row scaling (fixed row sums a/b) cancels exactly, and
        # a global constant of ∇g cancels against Σg = 1. Removing them
        # before the sup-norm rescale keeps γ' from being throttled by
        # directions the projection would discard anyway.
        gq = grads.grad_q - grads.grad_q.mean(axis=1, keepdims=True)
        gr = grads.grad_r - grads.grad_r.mean(axis=1, keepdims=True)
        gg = grads.grad_g - grads.grad_g.mean()
        gamma = self.gamma / scale
        if self.gamma_rescale:
            sup = jnp.maximum(jnp.max(jnp.abs(gq)),
                              jnp.maximum(jnp.max(jnp.abs(gr)),
                                          jnp.max(jnp.abs(gg))))
            # the _TINY floor also keeps γ0/sup f32-finite at exact
            # stationarity (γ0/1e-38 is inf, and inf·0 = NaN)
            gamma = gamma / jnp.maximum(sup, _TINY)
        # kernel of the KL-prox mirror step: K = prev^(1-γε) ⊙ exp(-γ ∇).
        # The combination exponent must stay in [0, 1]: the rescaled γ is
        # unbounded (γ0/sup, with sup → _TINY at stationarity), so for
        # ε > 0 an unguarded 1 - γε flips sign and overflows the kernel.
        # Clamping at 0 degrades gracefully to the fully-entropic step.
        carry = jnp.maximum(1.0 - gamma * self.epsilon, 0.0)
        K1 = jnp.exp(carry * jnp.log(jnp.maximum(Q, _TINY)) - gamma * gq)
        K2 = jnp.exp(carry * jnp.log(jnp.maximum(R, _TINY)) - gamma * gr)
        k3 = jnp.exp(carry * jnp.log(jnp.maximum(g, _TINY)) - gamma * gg)
        return lr_dykstra(K1, K2, k3, a, b, self.g_floor,
                          self.inner_iters, self.inner_tol)


# pytree registration must precede registry registration (register_solver
# auto-registers unregistered classes with ε as the only dynamic leaf;
# here γ is dynamic too)
register_pytree_dataclass(
    LowRankGWSolver,
    data_fields=("epsilon", "gamma", "fault"),
    meta_fields=("rank", "cost_rank", "gamma_rescale", "g_floor",
                 "init", "init_blend", "outer_iters", "inner_iters",
                 "tol", "inner_tol", "max_rescues", "rescue_factor",
                 "trace"))
register_solver("lowrank_gw")(LowRankGWSolver)
