"""Initialization of the low-rank factors (Q, R, g).

Two strategies:

* ``random`` — the historical full-rank positive init with exact outer
  marginals (see :func:`random_init`); column symmetry is broken but the
  init carries no information, so mirror descent burns its first ~100
  steps rediscovering coarse structure;
* ``anchors`` — FPS/anchor-seeded structured init: compress each side
  to r anchors (coordinate-space FPS for point clouds —
  ``multiscale/anchors.fps_points`` — never an m×n or n×n object; cost
  FPS + medoid refinement for precomputed geometries), solve the tiny
  r×r anchor-level dense GW, and lift its coupling P to factors

      Q₀[i, c] = a_i·1[cx(i) = c]              (column mass wx_c)
      R₀[j, c] = b_j·P[c, cy(j)] / wy_{cy(j)}
      g₀       = wx

  which is *exactly* the quantized expansion of P in factored form:
  row sums are (a, b) and both column sums equal g₀, so the init is
  already feasible, and it encodes the anchor-level correspondence the
  mirror descent would otherwise have to find from noise. A ``blend``
  fraction of the uniform rank-one coupling is mixed in to keep every
  entry strictly positive (pure cluster indicators have zeros, which
  are absorbing under the multiplicative MD kernel).

Cost: O((m + n)·r·d) for the FPS/assignment plus an r×r dense GW —
negligible against a single outer MD step, and linear in m + n, so the
low-rank solver's complexity contract survives. BENCH_PR10.json records
the convergence improvement at the default 300-step budget.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.multiscale.anchors import (
    farthest_point_sampling,
    fps_points,
    medoid_refinement,
)

__all__ = ["random_init", "anchor_init"]


def random_init(key, a, b, rank: int):
    """Random full-rank positive init with exact outer marginals.

    A rank-one init (Q = a gᵀ) is a *fixed point* of the mirror-descent
    kernels — every gradient column coincides, so the factors stay
    rank-one forever. The init must therefore break column symmetry;
    Dykstra restores the inner-marginal constraints on the first step.
    """
    kq, kr = jax.random.split(key)
    g = jnp.full((rank,), 1.0 / rank, a.dtype)
    zq = jax.random.uniform(kq, (a.shape[0], rank), a.dtype,
                            minval=0.5, maxval=1.5)
    zr = jax.random.uniform(kr, (b.shape[0], rank), b.dtype,
                            minval=0.5, maxval=1.5)
    Q = a[:, None] * zq / zq.sum(axis=1, keepdims=True)
    R = b[:, None] * zr / zr.sum(axis=1, keepdims=True)
    return Q, R, g


def _side_anchors(key, geom, k: int):
    """(anchor cost (k, k), assign (n,), cluster mass (k,)) for one side.

    Point clouds stay in coordinate space (no n×n); precomputed costs
    reuse the multiscale FPS + one medoid-refinement round.
    """
    w = geom.weights
    if geom.points is not None:
        idx, assign = fps_points(key, geom.points, w, k)
        pa = geom.points[idx]
        sq = jnp.sum(pa * pa, axis=-1)
        C = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * (pa @ pa.T), 0.0)
    else:
        D = geom.cost_matrix
        idx = farthest_point_sampling(key, D, w, k)
        idx, assign = medoid_refinement(D, w, idx, 1)
        C = D[idx][:, idx]
    mass = jax.ops.segment_sum(w, assign, num_segments=k)
    return C, assign, mass


def anchor_init(key, problem, rank: int, *, blend: float = 0.2,
                gw_outer: int = 50, gw_inner: int = 100):
    """FPS/anchor-seeded (Q, R, g) — see the module docstring.

    blend — uniform-coupling mixing fraction τ ∈ (0, 1): τ = 0 would
    leave exact zeros (absorbing under MD), τ = 1 is the rank-one fixed
    point; the default keeps the structure dominant.
    """
    # local import: lowrank.init ← api.solvers would otherwise cycle at
    # module import time (api.solvers → api.driver → diff → health)
    from repro.api.geometry import Geometry
    from repro.api.problem import QuadraticProblem
    from repro.api.solvers import DenseGWSolver

    a = problem.geom_x.weights
    b = problem.geom_y.weights
    kx, ky = jax.random.split(key)
    Cax, assign_x, wx = _side_anchors(kx, problem.geom_x, rank)
    Cay, assign_y, wy = _side_anchors(ky, problem.geom_y, rank)

    # tiny r×r anchor-level GW — prox PGA, ε scaled to the anchor costs
    eps = 0.05 * (jnp.mean(Cax) + jnp.mean(Cay) + 1e-12)
    tiny = DenseGWSolver(epsilon=eps, outer_iters=gw_outer,
                         inner_iters=gw_inner, tol=1e-9)
    anchor_problem = QuadraticProblem(
        Geometry(Cax, wx, validate=False), Geometry(Cay, wy, validate=False),
        loss=problem.loss, validate=False)
    P = tiny.run(anchor_problem).coupling                       # (r, r)

    # lift: quantized expansion of P in factored form (feasible by
    # construction — see module docstring), blended with uniform
    u = 1.0 / rank
    Q_s = a[:, None] * jax.nn.one_hot(assign_x, rank, dtype=a.dtype)
    denom = jnp.maximum(wy, 1e-38)
    R_s = b[:, None] * (P[:, assign_y].T / denom[assign_y][:, None])
    Q = (1.0 - blend) * Q_s + blend * (a[:, None] * u)
    R = (1.0 - blend) * R_s + blend * (b[:, None] * u)
    g = (1.0 - blend) * wx + blend * u
    return Q, R, g
