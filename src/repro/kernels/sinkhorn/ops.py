"""jit'd wrapper: VMEM-size gate + fallback to the jnp Sinkhorn."""
from __future__ import annotations

from typing import Optional

from repro.core.sinkhorn import sinkhorn as sinkhorn_jnp
from repro.kernels import dispatch
from repro.kernels.sinkhorn.sinkhorn import sinkhorn_pallas

dispatch.register("sinkhorn", default_block=0,
                  description="VMEM-resident Sinkhorn scaling loop")


def sinkhorn(a, b, K, iters: int = 50, interpret: Optional[bool] = None):
    m, n = K.shape
    if m * n * 4 <= dispatch.vmem_budget():
        return sinkhorn_pallas(a, b, K, iters=iters,
                               interpret=dispatch.interpret_mode(interpret))
    return sinkhorn_jnp(a, b, K, iters)
