"""jit'd wrapper: VMEM-size gate + fallback to the jnp Sinkhorn."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sinkhorn import sinkhorn as sinkhorn_jnp
from repro.kernels.sinkhorn.sinkhorn import sinkhorn_pallas

_INTERPRET = jax.default_backend() != "tpu"
_VMEM_BUDGET = 8 * 2**20        # 8 MiB for the resident K (f32)


def sinkhorn(a, b, K, iters: int = 50):
    m, n = K.shape
    if m * n * 4 <= _VMEM_BUDGET:
        return sinkhorn_pallas(a, b, K, iters=iters, interpret=_INTERPRET)
    return sinkhorn_jnp(a, b, K, iters)
