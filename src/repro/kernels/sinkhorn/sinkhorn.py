"""Pallas TPU kernel: VMEM-resident Sinkhorn scaling loop.

The paper's inner loop (Alg. 2 step 7) runs H matvec pairs against the
same kernel matrix. On the grid support that matrix is a dense
(s_r × s_c) block — small enough for VMEM — so the entire H-iteration
loop runs with K resident on-chip: **zero HBM traffic inside the loop**
(vs 2·H·s_r·s_c reads for the naive version; this is the memory-term
optimization for the paper's own technique, cf. EXPERIMENTS.md §Perf).

Single grid step; u/v iterates in VMEM scratch; matvecs hit the MXU via
dot_general.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, k_ref, t_ref, u_scr, v_scr, *, iters: int):
    K = k_ref[...].astype(jnp.float32)                   # resident (m, n)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    u_scr[...] = jnp.ones_like(u_scr)
    v_scr[...] = jnp.ones_like(v_scr)

    def body(_, carry):
        u, v = carry
        Kv = jax.lax.dot_general(K, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        u = jnp.where(Kv > 0, a / jnp.where(Kv > 0, Kv, 1.0), 0.0)
        Ku = jax.lax.dot_general(K, u, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        v = jnp.where(Ku > 0, b / jnp.where(Ku > 0, Ku, 1.0), 0.0)
        return (u, v)

    u, v = jax.lax.fori_loop(0, iters, body, (u_scr[...], v_scr[...]))
    t_ref[...] = (u[:, None] * K * v[None, :]).astype(t_ref.dtype)


@functools.partial(jax.jit, static_argnames=("iters", "interpret"))
def sinkhorn_pallas(a, b, K, iters: int = 50, interpret: bool = True):
    """a: (m,), b: (n,), K: (m, n) — returns the coupling T (m, n) f32.

    VMEM budget: K must fit on-chip; ops.py enforces the size cap and
    falls back to the jnp path above it.
    """
    m, n = K.shape
    from repro.kernels.flash_attention.flash_attention import pltpu_or_fallback
    return pl.pallas_call(
        functools.partial(_kernel, iters=iters),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu_or_fallback((m,), jnp.float32),
                        pltpu_or_fallback((n,), jnp.float32)],
        interpret=interpret,
    )(a, b, K)
