"""Pure-jnp oracle: H Sinkhorn scaling iterations on a dense kernel matrix."""
from __future__ import annotations

import jax.numpy as jnp


def sinkhorn_ref(a, b, K, iters: int):
    u = jnp.ones_like(a)
    v = jnp.ones_like(b)
    for _ in range(iters):
        Kv = K @ v
        u = jnp.where(Kv > 0, a / jnp.where(Kv > 0, Kv, 1.0), 0.0)
        Ku = K.T @ u
        v = jnp.where(Ku > 0, b / jnp.where(Ku > 0, Ku, 1.0), 0.0)
    return u[:, None] * K * v[None, :]
