"""Unified kernel dispatch: backend, padding, block sizing, micro-autotune.

Every kernel family (spar_cost, gw_cost, sinkhorn, flash_attention, ssd)
routes its backend / interpret / padding / block-size decisions through
this module instead of carrying its own copy. Two rules it enforces:

1. **No import-time backend freezing.** ``interpret_mode()`` resolves the
   Pallas interpret flag *at call time*, so ``jax.config`` updates or
   distributed init that run after the module import are respected
   (the old per-``ops.py`` ``_INTERPRET = jax.default_backend() != "tpu"``
   globals evaluated before any of that could run).
2. **One knob surface.** Block sizes resolve as
   explicit argument > ``REPRO_BLOCK_<FAMILY>`` env var > autotune cache >
   registry default, and memory budgets come from one place, so
   benchmarks and production code can tune without touching kernel code.

Caveat: inside a ``jax.jit``'d solver, "call time" means *trace time* —
an executable cached for a given shape/static-arg key bakes in the env
values seen at its first trace. Changing ``REPRO_*`` knobs mid-process
only affects new traces; clear the jit cache (or use fresh shapes) to
re-resolve.

See DESIGN.md §2 for the architecture discussion.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.obs.registry import registry as _obs_registry

_TRUTHY = {"1", "true", "yes", "on"}
_FALSY = {"0", "false", "no", "off"}


# ---------------------------------------------------------------------------
# Backend / interpret resolution (call time, never import time)
# ---------------------------------------------------------------------------

def backend() -> str:
    """The active JAX backend, resolved now (not at import)."""
    return jax.default_backend()


def interpret_mode(override: Optional[bool] = None) -> bool:
    """Whether Pallas kernels should run in interpret mode.

    Priority: explicit ``override`` > ``REPRO_PALLAS_INTERPRET`` env
    ("1"/"0"/"auto") > auto (interpret everywhere except TPU, where the
    Mosaic path compiles).
    """
    if override is not None:
        return override
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "auto").strip().lower()
    if env in _TRUTHY:
        return True
    if env in _FALSY:
        return False
    return backend() != "tpu"


# ---------------------------------------------------------------------------
# Memory budgets (env-overridable)
# ---------------------------------------------------------------------------

def _env_bytes(name: str, default: int) -> int:
    raw = os.environ.get(name)
    if not raw:
        return default
    return int(float(raw))


def vmem_budget() -> int:
    """On-chip budget for VMEM-resident operands (sinkhorn's kernel K)."""
    return _env_bytes("REPRO_VMEM_BUDGET", 8 * 2**20)


def materialize_budget() -> int:
    """HBM budget for materializing the (s, s) spar_cost loss matrix."""
    return _env_bytes("REPRO_SPAR_MATERIALIZE_BUDGET", 512 * 2**20)


# ---------------------------------------------------------------------------
# Kernel family registry
# ---------------------------------------------------------------------------

@dataclass
class KernelFamily:
    name: str
    default_block: int
    description: str = ""


_REGISTRY: dict[str, KernelFamily] = {}


def register(name: str, default_block: int, description: str = "") -> KernelFamily:
    """Register (or re-register, idempotently) a kernel family."""
    fam = KernelFamily(name, default_block, description)
    _REGISTRY[name] = fam
    return fam


def registry() -> dict[str, KernelFamily]:
    return dict(_REGISTRY)


def block_size(family: str, override: Optional[int] = None,
               cap: Optional[int] = None) -> int:
    """Resolve the block size for a kernel family.

    Priority: ``override`` arg > ``REPRO_BLOCK_<FAMILY>`` env > autotune
    cache (populated by :func:`autotune`) > registry default. ``cap``
    clamps from above (e.g. to the problem size) while keeping ≥ 1.
    """
    bs, source = override, "override"
    if bs is None:
        env = os.environ.get(f"REPRO_BLOCK_{family.upper()}")
        if env:
            bs, source = int(env), "env"
    if bs is None:
        bs, source = _AUTOTUNE_CACHE.get(family), "autotune"
    if bs is None:
        fam = _REGISTRY.get(family)
        bs = fam.default_block if fam is not None else 128
        source = "default"
    # per-family resolution counts: a production trace where "default"
    # dominates a tuned family means the autotune cache never warmed.
    # NB: under jit this counts *traces*, not executions (see module
    # docstring caveat) — executable reuse never re-resolves.
    _obs_registry().counter(
        "repro_kernel_block_resolutions_total",
        "block_size() resolutions by family and winning source",
        family=family, source=source).inc()
    if cap is not None:
        bs = min(bs, cap)
    return max(int(bs), 1)


# ---------------------------------------------------------------------------
# Padding helpers
# ---------------------------------------------------------------------------

def pad_to_multiple(x, mults):
    """Zero-pad each dim of ``x`` up to a multiple of ``mults[i]``.

    Returns ``(padded, original_shape)``; no-op (no copy) when already
    aligned. Slice back with :func:`unpad`.
    """
    pads = [(0, (-x.shape[i]) % mults[i]) for i in range(x.ndim)]
    if any(p for _, p in pads):
        return jnp.pad(x, pads), x.shape
    return x, x.shape


def pad_dim(x, mult: int, axis: int = 0, value=0):
    """Pad one axis of ``x`` up to a multiple of ``mult`` with ``value``."""
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def unpad(x, shape):
    """Slice ``x`` back to ``shape`` (inverse of :func:`pad_to_multiple`)."""
    if tuple(x.shape) == tuple(shape):
        return x
    return x[tuple(slice(0, d) for d in shape)]


# ---------------------------------------------------------------------------
# Micro-autotune
# ---------------------------------------------------------------------------

_AUTOTUNE_CACHE: dict[str, int] = {}
_AUTOTUNE_RECORDS: list[dict] = []


def autotune(family: str, candidates: Iterable[int],
             bench_fn: Callable[[int], object], reps: int = 3,
             flops_per_call: Optional[float] = None,
             bytes_per_call: Optional[float] = None) -> Optional[int]:
    """Time ``bench_fn(block)`` over candidate block sizes; cache the best.

    The winner feeds subsequent :func:`block_size` resolutions for
    ``family`` (below any explicit/env override) and is appended to the
    in-process record list that ``benchmarks/roofline.py`` reports.
    Candidates that raise are skipped (e.g. blocks over the VMEM budget).

    ``flops_per_call`` / ``bytes_per_call`` (caller-supplied analytic
    counts for one ``bench_fn`` invocation) turn the winner's timing into
    achieved GFLOP/s and GB/s — recorded on the autotune record and
    exported as ``repro_autotune_*`` gauges for roofline placement.
    """
    timings: dict[int, float] = {}
    for cand in candidates:
        try:
            jax.block_until_ready(bench_fn(cand))        # compile + warm
            t0 = time.perf_counter()
            for _ in range(reps):
                jax.block_until_ready(bench_fn(cand))
            timings[int(cand)] = (time.perf_counter() - t0) / reps
        except Exception:  # noqa: BLE001 — invalid candidate, keep sweeping
            continue
    if not timings:
        return None
    best = min(timings, key=timings.get)
    best_s = timings[best]
    _AUTOTUNE_CACHE[family] = best
    record = {
        "family": family,
        "backend": backend(),
        "best_block": best,
        "timings_s": {str(k): v for k, v in timings.items()},
    }
    reg = _obs_registry()
    reg.gauge("repro_autotune_best_block", "autotune-selected block size",
              family=family, backend=backend()).set(best)
    reg.gauge("repro_autotune_best_time_seconds",
              "best per-call time of the autotune winner",
              family=family, backend=backend()).set(best_s)
    if flops_per_call is not None and best_s > 0:
        record["gflops"] = flops_per_call / best_s / 1e9
        reg.gauge("repro_autotune_gflops",
                  "achieved GFLOP/s of the autotune winner (roofline y)",
                  family=family, backend=backend()).set(record["gflops"])
    if bytes_per_call is not None and best_s > 0:
        record["gbytes_per_s"] = bytes_per_call / best_s / 1e9
        reg.gauge("repro_autotune_gbytes_per_s",
                  "achieved GB/s of the autotune winner",
                  family=family, backend=backend()).set(
                      record["gbytes_per_s"])
    _AUTOTUNE_RECORDS.append(record)
    return best


def autotune_records() -> list[dict]:
    return list(_AUTOTUNE_RECORDS)


def autotune_artifact_dir() -> Path:
    return Path(__file__).resolve().parents[3] / "artifacts" / "autotune"


def dump_autotune_records(path: Optional[os.PathLike] = None) -> Optional[Path]:
    """Persist this process's autotune records for roofline reporting."""
    if not _AUTOTUNE_RECORDS:
        return None
    if path is None:
        path = autotune_artifact_dir() / f"{backend()}.json"
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as f:
        json.dump(_AUTOTUNE_RECORDS, f, indent=1)
    return path


def clear_autotune_cache() -> None:
    _AUTOTUNE_CACHE.clear()
    _AUTOTUNE_RECORDS.clear()
