"""jit'd public wrapper for the gw_cost kernel: padding + dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gw_cost.gw_cost import gw_cost_pallas

# interpret=True on CPU (validation); on TPU the Mosaic path compiles.
_INTERPRET = jax.default_backend() != "tpu"


def _pad_to(x, mults):
    pads = [(0, (-x.shape[i]) % mults[i]) for i in range(x.ndim)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads), x.shape
    return x, x.shape


def gw_cost(A, B, T, loss: str = "l1", block: int = 32):
    """C[k,m] = Σ_{l,p} L(A[k,l], B[m,p]) T[l,p], padded + unpadded."""
    K, M = A.shape[0], B.shape[0]
    A_p, _ = _pad_to(A, (block, block))
    B_p, _ = _pad_to(B, (block, block))
    T_p, _ = _pad_to(T, (block, block))
    # zero-padded T rows/cols contribute L(A,B)*0 = 0; padded A/B rows only
    # produce extra output rows/cols, sliced away below.
    out = gw_cost_pallas(A_p, B_p, T_p, loss=loss, bk=block, bm=block,
                         bl=block, bp=block, interpret=_INTERPRET)
    return out[:K, :M]
