"""jit'd public wrapper for the gw_cost kernel: padding + dispatch."""
from __future__ import annotations

from typing import Optional

from repro.kernels import dispatch
from repro.kernels.gw_cost.gw_cost import gw_cost_pallas

dispatch.register("gw_cost", default_block=32,
                  description="grid GW cost assembly (4-D contraction)")


def gw_cost(A, B, T, loss: str = "l1", block: Optional[int] = None,
            interpret: Optional[bool] = None):
    """C[k,m] = Σ_{l,p} L(A[k,l], B[m,p]) T[l,p], padded + unpadded."""
    K, M = A.shape[0], B.shape[0]
    block = dispatch.block_size("gw_cost", block)
    A_p, _ = dispatch.pad_to_multiple(A, (block, block))
    B_p, _ = dispatch.pad_to_multiple(B, (block, block))
    T_p, _ = dispatch.pad_to_multiple(T, (block, block))
    # zero-padded T rows/cols contribute L(A,B)*0 = 0; padded A/B rows only
    # produce extra output rows/cols, sliced away below.
    out = gw_cost_pallas(A_p, B_p, T_p, loss=loss, bk=block, bm=block,
                         bl=block, bp=block,
                         interpret=dispatch.interpret_mode(interpret))
    return out[:K, :M]
