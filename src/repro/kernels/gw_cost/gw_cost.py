"""Pallas TPU kernel: grid GW cost assembly for arbitrary ground costs.

C[k, m] = Σ_{l,p} L(A[k,l], B[m,p]) T[l,p]

TPU adaptation of the paper's O(s²) sparse cost assembly: on the grid
support the computation is a dense 4-D contraction. The kernel tiles the
output over (k, m) and streams (l, p) reduction tiles through VMEM,
accumulating in the output block (revisited across the minor grid dims —
standard Pallas accumulation pattern). The (bk, bl, bm, bp) elementwise
tile lives entirely in VMEM/VREGs; no HBM intermediate is ever formed.

For decomposable L the two-matmul MXU path (core/grid_gw.py) is used
instead; this kernel is what makes *arbitrary* costs (the paper's ℓ1 case)
TPU-efficient.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _loss_tile(loss: str, a, b):
    if loss == "l1":
        return jnp.abs(a - b)
    if loss == "l2":
        d = a - b
        return d * d
    if loss == "kl":
        eps = 1e-10
        return a * (jnp.log(jnp.maximum(a, eps)) -
                    jnp.log(jnp.maximum(b, eps))) - a + b
    raise ValueError(loss)


def _kernel(a_ref, b_ref, t_ref, o_ref, *, loss: str, n_l: int, n_p: int):
    li = pl.program_id(2)
    pi = pl.program_id(3)

    @pl.when((li == 0) & (pi == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)          # (bk, bl)
    b = b_ref[...].astype(jnp.float32)          # (bm, bp)
    t = t_ref[...].astype(jnp.float32)          # (bl, bp)
    # (bk, bl, bm, bp) elementwise tile, contracted over (l, p)
    e = _loss_tile(loss, a[:, :, None, None], b[None, None, :, :])
    contrib = jnp.einsum("klmp,lp->km", e, t)
    o_ref[...] += contrib


@functools.partial(jax.jit,
                   static_argnames=("loss", "bk", "bm", "bl", "bp",
                                    "interpret"))
def gw_cost_pallas(A, B, T, loss: str = "l1", bk: int = 32, bm: int = 32,
                   bl: int = 32, bp: int = 32, interpret: bool = True):
    """A: (K, L), B: (M, P), T: (L, P) -> C: (K, M) float32.

    Dims must be multiples of the block sizes (ops.py pads).
    """
    K, L = A.shape
    M, P = B.shape
    grid = (K // bk, M // bm, L // bl, P // bp)
    return pl.pallas_call(
        functools.partial(_kernel, loss=loss, n_l=grid[2], n_p=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bl), lambda k, m, l, p: (k, l)),
            pl.BlockSpec((bm, bp), lambda k, m, l, p: (m, p)),
            pl.BlockSpec((bl, bp), lambda k, m, l, p: (l, p)),
        ],
        out_specs=pl.BlockSpec((bk, bm), lambda k, m, l, p: (k, m)),
        out_shape=jax.ShapeDtypeStruct((K, M), jnp.float32),
        interpret=interpret,
    )(A, B, T)
