"""Pure-jnp oracle for the grid GW cost assembly.

C[k, m] = Σ_{l,p} L(A[k,l], B[m,p]) T[l,p]   — the paper's O(s²) hotspot
restructured on the grid support (DESIGN.md §4).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import ground_cost as gc


def gw_cost_ref(A, B, T, loss: str):
    L = gc.get_loss(loss)
    E = L(A[:, :, None, None], B[None, None, :, :])   # (K, L, M, P)
    return jnp.einsum("klmp,lp->km", E.astype(jnp.float32),
                      T.astype(jnp.float32))
