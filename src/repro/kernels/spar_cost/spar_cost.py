"""Pallas TPU kernels: fused COO spar_cost assembly with affine epilogue.

The paper's O(s²) hotspot on the COO support is

    C̃(T̃)_k = Σ_l L(Cx[r_k, r_l], Cy[c_k, c_l]) T̃_l,      k ∈ [s]

and the outer PGA step only ever consumes the *log-kernel*
logK = -C/ε + log w (+ log T̃ + linear terms). Both kernels below therefore
compute the affine form

    out = L-matvec(t) + off

with fp32 accumulation: callers pre-scale ``t`` by -α/ε and fold
log w / log T̃ / the FGW linear term into ``off``, so one (s,) vector (the
log-kernel itself) is the only thing written back to HBM per outer
iteration — no C, no K, no separate logK intermediates.

Two entry points (see DESIGN.md §3):

- ``spar_cost_pallas`` — gather-fused. ``rows``/``cols`` ride in via
  scalar prefetch; each (bk, bl) tile of Gx = Cx[rows][:, rows] (resp. Gy)
  is gathered *inside* the kernel from the VMEM-resident row panels
  Xr = Cx[rows], Yc = Cy[cols], so the (s, s) support blocks never touch
  HBM. Memory high-water: O(s·(m+n)) for the panels.
- ``spar_matvec_pallas`` — materialized-support fast mode. The loss matrix
  Lmat[k, l] = L(Gx, Gy) is **constant across all outer iterations**
  (rows/cols are fixed after sampling), so when the HBM budget allows it
  is hoisted once and every iteration collapses to this fused
  matvec + epilogue with zero gathers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _loss_tile(loss: str, a, b):
    if loss == "l1":
        return jnp.abs(a - b)
    if loss == "l2":
        d = a - b
        return d * d
    if loss == "kl":
        eps = 1e-10
        return a * (jnp.log(jnp.maximum(a, eps)) -
                    jnp.log(jnp.maximum(b, eps))) - a + b
    raise ValueError(loss)


def _fused_kernel(rows_ref, cols_ref, xr_ref, yc_ref, t_ref, off_ref, o_ref,
                  *, loss: str, bl: int, n_l: int):
    li = pl.program_id(1)

    @pl.when(li == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ridx = rows_ref[pl.ds(li * bl, bl)]                  # (bl,) prefetched
    cidx = cols_ref[pl.ds(li * bl, bl)]
    gx = xr_ref[...].astype(jnp.float32)[:, ridx]        # (bk, bl) in VMEM
    gy = yc_ref[...].astype(jnp.float32)[:, cidx]
    t = t_ref[...].astype(jnp.float32)[0]                # (bl,)
    e = _loss_tile(loss, gx, gy)
    o_ref[...] += jax.lax.dot_general(
        e, t, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[None, :]

    @pl.when(li == n_l - 1)
    def _epilogue():
        o_ref[...] += off_ref[...].astype(jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("loss", "bk", "bl", "interpret"))
def spar_cost_pallas(Xr, Yc, rows, cols, t, off, loss: str = "l2",
                     bk: int = 256, bl: int = 256, interpret: bool = True):
    """Gather-fused COO cost: out = L(Xr[:, rows], Yc[:, cols]) @ t + off.

    Xr: (s_p, m) = Cx[rows], Yc: (s_p, n) = Cy[cols] row panels (gathered
    once per support, outside); rows/cols: (s_p,) int32; t, off: (s_p,).
    s_p must be a multiple of bk and bl (ops.py pads; padded tail has
    t = 0 so it contributes nothing, and out rows ≥ s are sliced away).
    Returns (s_p,) float32.
    """
    s_p, m = Xr.shape
    n = Yc.shape[1]
    grid = (s_p // bk, s_p // bl)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, m), lambda k, l, r, c: (k, 0)),
            pl.BlockSpec((bk, n), lambda k, l, r, c: (k, 0)),
            pl.BlockSpec((1, bl), lambda k, l, r, c: (0, l)),
            pl.BlockSpec((1, bk), lambda k, l, r, c: (0, k)),
        ],
        out_specs=pl.BlockSpec((1, bk), lambda k, l, r, c: (0, k)),
    )
    out = pl.pallas_call(
        functools.partial(_fused_kernel, loss=loss, bl=bl, n_l=grid[1]),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, s_p), jnp.float32),
        interpret=interpret,
    )(rows.astype(jnp.int32), cols.astype(jnp.int32),
      Xr, Yc, t.reshape(1, s_p), off.reshape(1, s_p))
    return out[0]


def _matvec_kernel(l_ref, t_ref, off_ref, o_ref, *, n_l: int):
    li = pl.program_id(1)

    @pl.when(li == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    lmat = l_ref[...].astype(jnp.float32)                # (bk, bl)
    t = t_ref[...].astype(jnp.float32)[0]                # (bl,)
    o_ref[...] += jax.lax.dot_general(
        lmat, t, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[None, :]

    @pl.when(li == n_l - 1)
    def _epilogue():
        o_ref[...] += off_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bk", "bl", "interpret"))
def spar_matvec_pallas(Lmat, t, off, bk: int = 256, bl: int = 256,
                       interpret: bool = True):
    """Materialized-support fast mode: out = Lmat @ t + off, tiled fp32.

    Lmat: (s_p, s_p) precomputed loss values; t, off: (s_p,). Returns
    (s_p,) float32. s_p must be a multiple of bk and bl.
    """
    s_p = Lmat.shape[0]
    grid = (s_p // bk, s_p // bl)
    out = pl.pallas_call(
        functools.partial(_matvec_kernel, n_l=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bl), lambda k, l: (k, l)),
            pl.BlockSpec((1, bl), lambda k, l: (0, l)),
            pl.BlockSpec((1, bk), lambda k, l: (0, k)),
        ],
        out_specs=pl.BlockSpec((1, bk), lambda k, l: (0, k)),
        out_shape=jax.ShapeDtypeStruct((1, s_p), jnp.float32),
        interpret=interpret,
    )(Lmat, t.reshape(1, s_p), off.reshape(1, s_p))
    return out[0]
