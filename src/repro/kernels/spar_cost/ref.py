"""Pure-jnp oracles for the COO spar_cost family.

``spar_cost_ref`` is the paper-faithful row-chunked ``lax.map`` assembly
(the pre-kernel hot path, kept as the correctness oracle and the CPU
fallback for supports too large to materialize). ``materialize_loss``
hoists the iteration-invariant loss matrix for the materialized fast mode.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import ground_cost as gc


def _chunked(rows, cols, chunk: int):
    s = rows.shape[0]
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    rows_p = jnp.pad(rows, (0, pad))
    cols_p = jnp.pad(cols, (0, pad))
    return (rows_p.reshape(n_chunks, chunk), cols_p.reshape(n_chunks, chunk))


def spar_cost_ref(Cx, Cy, rows, cols, tvals, loss: str, chunk: int = 1024):
    """C̃(T̃)_k = Σ_l L(Cx[r_k, r_l], Cy[c_k, c_l]) T̃_l for k ∈ [s].  O(s²).

    Row-chunked so the gathered (chunk, s) blocks stay cache/VMEM-sized.
    """
    L = gc.get_loss(loss)
    s = rows.shape[0]

    def one(args):
        rk, ck = args                      # (chunk,)
        Gx = Cx[rk][:, rows]               # (chunk, s)
        Gy = Cy[ck][:, cols]               # (chunk, s)
        return L(Gx, Gy) @ tvals           # (chunk,)

    out = lax.map(one, _chunked(rows, cols, chunk))
    return out.reshape(-1)[:s]


def materialize_loss(Cx, Cy, rows, cols, loss: str, chunk: int = None):
    """Lmat[k, l] = L(Cx[r_k, r_l], Cy[c_k, c_l]) — (s, s) float32.

    Iteration-invariant (the support is fixed after sampling), so the
    materialized mode computes it once and amortizes it over every outer
    iteration. Default is one vectorized gather — ~3× faster than
    chunking but with a ~3·s² transient (Gx, Gy, result), so callers
    must check that against their budget (ops.make_spar_cost_fn does);
    pass ``chunk`` to bound the transient to O(chunk·s) instead.
    """
    L = gc.get_loss(loss)
    if chunk is None:
        return L(Cx[rows][:, rows], Cy[cols][:, cols]).astype(jnp.float32)
    s = rows.shape[0]

    def one(args):
        rk, ck = args
        Gx = Cx[rk][:, rows]
        Gy = Cy[ck][:, cols]
        return L(Gx, Gy).astype(jnp.float32)

    out = lax.map(one, _chunked(rows, cols, chunk))
    return out.reshape(-1, s)[:s]
