"""Public wrappers + impl dispatch for the COO spar_cost kernel family.

Three interchangeable implementations of the affine contract
``fn(t, off) = L-matvec(t) + off`` (see spar_cost.py):

- ``"jnp"``          — row-chunked ``lax.map`` oracle (ref.py). Gathers the
                       (chunk, s) support blocks from HBM every call.
- ``"pallas"``       — gather-fused Pallas kernel; O(s·(m+n)) resident row
                       panels, per-tile gathers stay in VMEM.
- ``"materialized"`` — iteration-invariant loss matrix hoisted once
                       (O(s²) HBM, budget-gated); every call is a single
                       fused matvec + epilogue with zero gathers.

``make_spar_cost_fn`` hoists the per-support setup (padding, panel/loss
materialization) out of the outer PGA loop and returns the closure the
solvers scan with; ``"auto"`` picks materialized when the budget gate
allows, else the kernel path on TPU or the jnp oracle elsewhere.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.spar_cost.ref import materialize_loss, spar_cost_ref
from repro.kernels.spar_cost.spar_cost import (
    spar_cost_pallas,
    spar_matvec_pallas,
)

dispatch.register("spar_cost", default_block=256,
                  description="fused COO cost assembly (SPAR-GW hot path)")


def resolve_impl(impl: str, s: int) -> str:
    """Resolve ``"auto"`` to a concrete impl for a support of size s."""
    if impl != "auto":
        return impl
    if s * s * 4 <= dispatch.materialize_budget():
        return "materialized"
    return "pallas" if dispatch.backend() == "tpu" else "jnp"


def _block_and_pad(rows, cols, block: Optional[int]):
    s = rows.shape[0]
    b = dispatch.block_size("spar_cost", block, cap=s)
    s_p = -(-s // b) * b
    rows_p = dispatch.pad_dim(rows.astype(jnp.int32), b)
    cols_p = dispatch.pad_dim(cols.astype(jnp.int32), b)
    return b, s_p, rows_p, cols_p


def _vec(x, s_p: int):
    """Broadcast a scalar / (s,) offset to a zero-padded (s_p,) float32."""
    x = jnp.broadcast_to(jnp.asarray(x, jnp.float32),
                         (s_p,) if jnp.ndim(x) == 0 else jnp.shape(x))
    return dispatch.pad_dim(x, s_p) if x.shape[0] != s_p else x


def spar_cost_fused(Cx, Cy, rows, cols, t, off=0.0, loss: str = "l2",
                    block: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """One-shot gather-fused cost: L @ t + off on the COO support, (s,)."""
    s = rows.shape[0]
    b, s_p, rows_p, cols_p = _block_and_pad(rows, cols, block)
    Xr = Cx[rows_p]
    Yc = Cy[cols_p]
    out = spar_cost_pallas(Xr, Yc, rows_p, cols_p,
                           _vec(t, s_p), _vec(off, s_p), loss=loss,
                           bk=b, bl=b,
                           interpret=dispatch.interpret_mode(interpret))
    return out[:s]


def spar_matvec(Lmat, t, off=0.0, block: Optional[int] = None,
                interpret: Optional[bool] = None):
    """One-shot materialized-support matvec: Lmat @ t + off, (s,)."""
    s = Lmat.shape[0]
    b = dispatch.block_size("spar_cost", block, cap=s)
    Lp, _ = dispatch.pad_to_multiple(Lmat, (b, b))
    s_p = Lp.shape[0]
    out = spar_matvec_pallas(Lp, _vec(t, s_p), _vec(off, s_p), bk=b, bl=b,
                             interpret=dispatch.interpret_mode(interpret))
    return out[:s]


def make_spar_cost_fn(Cx, Cy, rows, cols, loss: str, impl: str = "auto",
                      chunk: int = 1024, block: Optional[int] = None,
                      interpret: Optional[bool] = None
                      ) -> Callable[..., jnp.ndarray]:
    """Build ``fn(t, off=0.0) -> (s,) f32`` computing L-matvec(t) + off.

    Per-support setup (impl resolution, padding, panel gathers or loss
    materialization) happens here, once; inside a jit'd solver XLA hoists
    it out of the outer ``lax.scan``, so every iteration pays only the
    fused matvec (materialized) or tiled gather+loss+matvec (pallas).
    """
    s = rows.shape[0]
    impl = resolve_impl(impl, s)

    if impl == "jnp":
        def fn(t, off=0.0):
            return spar_cost_ref(Cx, Cy, rows, cols, t, loss, chunk) + off
        return fn

    if impl == "pallas":
        b, s_p, rows_p, cols_p = _block_and_pad(rows, cols, block)
        Xr = Cx[rows_p]
        Yc = Cy[cols_p]
        itp = dispatch.interpret_mode(interpret)

        def fn(t, off=0.0):
            out = spar_cost_pallas(Xr, Yc, rows_p, cols_p,
                                   _vec(t, s_p), _vec(off, s_p), loss=loss,
                                   bk=b, bl=b, interpret=itp)
            return out[:s]
        return fn

    if impl == "materialized":
        # the gate bounds the resident s² matrix; the one-shot vectorized
        # gather additionally needs a ~3·s² transient (Gx, Gy, result) —
        # fall back to the O(chunk·s)-transient chunked build past that
        direct_ok = 3 * s * s * 4 <= dispatch.materialize_budget()
        Lmat = materialize_loss(Cx, Cy, rows, cols, loss,
                                None if direct_ok else chunk)
        if dispatch.interpret_mode(interpret):
            # No Mosaic on this backend: the affine form is a single XLA
            # matvec that fuses fine on its own; interpret-mode Pallas
            # would only add per-tile overhead (parity tests exercise the
            # kernel explicitly via spar_matvec(interpret=True)).
            def fn(t, off=0.0):
                return Lmat @ t.astype(jnp.float32) + off
            return fn
        b = dispatch.block_size("spar_cost", block, cap=s)
        Lp, _ = dispatch.pad_to_multiple(Lmat, (b, b))
        s_p = Lp.shape[0]

        def fn(t, off=0.0):
            out = spar_matvec_pallas(Lp, _vec(t, s_p), _vec(off, s_p),
                                     bk=b, bl=b, interpret=False)
            return out[:s]
        return fn

    raise ValueError(f"unknown spar_cost impl: {impl!r}")
