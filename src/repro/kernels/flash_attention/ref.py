"""Pure-jnp oracle: causal GQA attention."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """q: (B, S, H, hd); k, v: (B, S, K, hd), H = G*K. Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    s = s / jnp.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


import jax  # noqa: E402  (used above via jax.nn)
