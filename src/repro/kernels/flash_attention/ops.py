"""jit'd public wrapper: (B,S,H,hd)/(B,S,K,hd) layout + GQA flattening."""
from __future__ import annotations

from typing import Optional

from repro.kernels import dispatch
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas

dispatch.register("flash_attention", default_block=128,
                  description="causal GQA flash attention (online softmax)")


def flash_attention(q, k, v, causal: bool = True, bq: Optional[int] = None,
                    bk: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """q: (B, S, H, hd); k, v: (B, S, K, hd). Causal GQA attention."""
    assert causal, "kernel implements the causal (LM) case"
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    bq = dispatch.block_size("flash_attention", bq, cap=S)
    bk = dispatch.block_size("flash_attention", bk, cap=S)
    # (B, S, H, hd) -> (B*H, S, hd) with head-major flattening so that
    # q head b*H + h maps to kv head (b*H + h)//G == b*K + h//G.
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    out = flash_attention_pallas(qf, kf, vf, groups=G, bq=bq, bk=bk,
                                 interpret=dispatch.interpret_mode(interpret))
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
