"""Pallas TPU kernel: causal GQA flash attention (online softmax).

Grid: (batch*heads, q_tiles, kv_tiles) — kv minor-most so the (m, l, acc)
running statistics live in VMEM scratch across the kv sweep for one q tile.
GQA is handled in the index maps: head ``h`` reads kv head ``h // G``, so
grouped KV is never replicated in HBM. Causal masking is positional per
tile; fully-masked tiles are skipped via ``pl.when`` (halves the work, the
same trick the XLA blockwise path can't express).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, bq: int, bk: int, n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip tiles strictly above the diagonal (causal)
    @pl.when(ki * bk <= qi * bq + bq - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                 # (bk, hd)
        v = v_ref[0].astype(jnp.float32)                 # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("groups", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q, k, v, groups: int, bq: int = 128,
                           bk: int = 128, interpret: bool = True):
    """q: (BH, S, hd) flattened batch*heads; k, v: (BK, S, hd) flattened
    batch*kv_heads with BH = BK * groups. Causal. Returns (BH, S, hd)."""
    BH, S, hd = q.shape
    bq = min(bq, S)
    bk = min(bk, S)
    grid = (BH, S // bq, S // bk)
    kv_index = lambda b, qi, ki: (b // groups, ki, 0)
    return pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / (hd ** 0.5), bq=bq, bk=bk,
                          n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, hd), kv_index),
            pl.BlockSpec((1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu_or_fallback((bq,), jnp.float32),
            pltpu_or_fallback((bq,), jnp.float32),
            pltpu_or_fallback((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def pltpu_or_fallback(shape, dtype):
    """VMEM scratch on TPU; plain pallas scratch in interpret mode."""
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        import jax.experimental.pallas as pl_
        return pl_.MemorySpace.ANY(shape, dtype)
