"""Pallas TPU kernels (validated in interpret mode on CPU; Mosaic on TPU):

  gw_cost/          grid GW cost assembly — the paper's O(s^2) hotspot
  flash_attention/  causal GQA online-softmax attention
  sinkhorn/         VMEM-resident Sinkhorn scaling loop
  ssd/              Mamba2 SSD intra-chunk (masked-decay) block

Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper + dispatch), ref.py (pure-jnp oracle); sweeps in tests/test_kernels.py.
"""
