"""Pallas TPU kernel: Mamba2 SSD intra-chunk (diagonal block) computation.

The SSD chunked algorithm's dominant memory cost is the (k, k, H) decay
tensor per chunk (zamba2: 128·128·112·4 B ≈ 7 MB per (batch, chunk) — and
the XLA path materializes it across all chunks at once). This kernel tiles
heads so each (k, k, h_tile) decay block lives only in VMEM/VREGs: the
G = C·Bᵀ Gram matrix hits the MXU once per (batch·chunk) and the per-head
masked-decay matmuls stream through on-chip.

Grid: (batch·chunks, H / h_tile). Inter-chunk recurrence (cheap, sequential)
stays in JAX (models/ssm.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xdt_ref, cs_ref, b_ref, c_ref, o_ref, *, k: int, ht: int):
    Bm = b_ref[0].astype(jnp.float32)                  # (k, N)
    Cm = c_ref[0].astype(jnp.float32)                  # (k, N)
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (k, k)
    cs = cs_ref[0].astype(jnp.float32)                 # (k, ht)
    decay = jnp.exp(cs[:, None, :] - cs[None, :, :])   # (k, k, ht) in VMEM
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (k, k), 0)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (k, k), 1)
    tri = (t_idx <= s_idx)[:, :, None]
    M = jnp.where(tri, G[:, :, None] * decay, 0.0)     # (k, k, ht)
    xdt = xdt_ref[0].astype(jnp.float32)               # (k, ht, P)
    # per-head (k, k) @ (k, P) matmuls on the MXU
    y = jax.lax.dot_general(
        M.transpose(2, 0, 1), xdt.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)            # (ht, k, P)
    o_ref[0] = y.transpose(1, 0, 2).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("h_tile", "interpret"))
def ssd_intra_pallas(xdt, cs, Bm, Cm, h_tile: int = 8,
                     interpret: bool = True):
    """xdt: (G, k, H, P) — G = batch*chunks; cs: (G, k, H);
    Bm/Cm: (G, k, N). Returns y: (G, k, H, P) float32."""
    Gn, k, H, P = xdt.shape
    N = Bm.shape[-1]
    while H % h_tile != 0:
        h_tile //= 2
    grid = (Gn, H // h_tile)
    return pl.pallas_call(
        functools.partial(_kernel, k=k, ht=h_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k, h_tile, P), lambda g, h: (g, 0, h, 0)),
            pl.BlockSpec((1, k, h_tile), lambda g, h: (g, 0, h)),
            pl.BlockSpec((1, k, N), lambda g, h: (g, 0, 0)),
            pl.BlockSpec((1, k, N), lambda g, h: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k, h_tile, P), lambda g, h: (g, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Gn, k, H, P), jnp.float32),
        interpret=interpret,
    )(xdt, cs, Bm, Cm)
