"""Pure-jnp oracle: Mamba2 SSD intra-chunk (diagonal block) output."""
from __future__ import annotations

import jax.numpy as jnp


def ssd_intra_ref(xdt, cs, Bm, Cm):
    """xdt: (k, H, P) inputs pre-multiplied by dt; cs: (k, H) within-chunk
    cumulative dA; Bm/Cm: (k, N). Returns y: (k, H, P) with
    y[s] = Σ_{t≤s} (C_s·B_t) exp(cs_s - cs_t) xdt[t]."""
    k = xdt.shape[0]
    decay = jnp.exp(cs[:, None, :] - cs[None, :, :])          # (k, k, H)
    tri = jnp.tril(jnp.ones((k, k), bool))
    G = Cm @ Bm.T                                             # (k, k)
    M = jnp.where(tri[:, :, None], G[:, :, None] * decay, 0.0)
    return jnp.einsum("sth,thp->shp", M, xdt)
