"""jit'd public wrapper for the SSD intra-chunk kernel."""
from __future__ import annotations

from typing import Optional

from repro.kernels import dispatch
from repro.kernels.ssd.ssd import ssd_intra_pallas

dispatch.register("ssd", default_block=8,
                  description="Mamba2 SSD intra-chunk scan (head tiles)")


def ssd_intra(xdt, cs, Bm, Cm, h_tile: Optional[int] = None,
              interpret: Optional[bool] = None):
    """xdt: (G, k, H, P), cs: (G, k, H), Bm/Cm: (G, k, N) -> (G, k, H, P)."""
    h_tile = dispatch.block_size("ssd", h_tile, cap=xdt.shape[2])
    return ssd_intra_pallas(xdt, cs, Bm, Cm, h_tile=h_tile,
                            interpret=dispatch.interpret_mode(interpret))
