"""jit'd public wrapper for the SSD intra-chunk kernel."""
from __future__ import annotations

import jax

from repro.kernels.ssd.ssd import ssd_intra_pallas

_INTERPRET = jax.default_backend() != "tpu"


def ssd_intra(xdt, cs, Bm, Cm, h_tile: int = 8):
    """xdt: (G, k, H, P), cs: (G, k, H), Bm/Cm: (G, k, N) -> (G, k, H, P)."""
    return ssd_intra_pallas(xdt, cs, Bm, Cm, h_tile=h_tile,
                            interpret=_INTERPRET)
