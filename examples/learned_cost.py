"""Learned ground cost: train an MLP feature map through fused GW.

Two noisy half-moon clouds carry 1-hot "color" features, but the second
cloud's colors are channel-permuted: the raw linear term ⟨M, T⟩ actively
*fights* the structural term. A small MLP (repro/models/layers.py) is
trained so that its embedding of the colors makes the fused objective
small — `fgw_loss` is the training loss, and its gradients reach the MLP
parameters through the Danskin envelope on the solver's fixed-point loop
(DESIGN.md §11): no unrolling, one cost contraction per step.

Run:  PYTHONPATH=src python examples/learned_cost.py
"""
import sys
sys.path.insert(0, ".")

import jax
import jax.numpy as jnp

import repro
from repro.diff import fgw_loss
from repro.models.layers import mlp, mlp_params
from repro.models.module import Builder
from repro.optim import adamw

n, d_feat, d_hidden = 40, 4, 16
key = jax.random.PRNGKey(0)
k_pts, k_noise, k_init = jax.random.split(key, 3)

# half-moon-ish structure with a 4-way color per point
t = jnp.linspace(0.0, jnp.pi, n)
x = jnp.stack([jnp.cos(t), jnp.sin(t)], axis=1)
x = x + 0.05 * jax.random.normal(k_pts, x.shape)
theta = 0.9
R = jnp.array([[jnp.cos(theta), -jnp.sin(theta)],
               [jnp.sin(theta), jnp.cos(theta)]])
y = x @ R.T + 0.05 * jax.random.normal(k_noise, x.shape)

colors = jnp.arange(n) % d_feat
feats_x = jax.nn.one_hot(colors, d_feat)
feats_y = jax.nn.one_hot((colors + 1) % d_feat, d_feat)   # permuted!

solver = repro.DenseGWSolver(epsilon=5e-2, outer_iters=80,
                             inner_iters=100, tol=0.0, inner_tol=0.0)
params = mlp_params(Builder("init", k_init), d_feat, d_hidden)


def loss_fn(p):
    return fgw_loss(x, y, mlp(p, feats_x), mlp(p, feats_y),
                    fused_penalty=0.5, solver=solver)


value_and_grad = jax.jit(jax.value_and_grad(loss_fn))
opt = adamw.init(params)
print(f"fused GW with raw (permuted) colors as M: "
      f"{float(fgw_loss(x, y, feats_x, feats_y, fused_penalty=0.5, solver=solver)):.5f}")
for step in range(30):
    value, grads = value_and_grad(params)
    params, opt, gnorm = adamw.update(grads, opt, params, 5e-3,
                                      weight_decay=0.0)
    if step % 5 == 0 or step == 29:
        print(f"step {step:3d}  fgw_loss={float(value):.5f}  "
              f"|grad|={float(gnorm):.3g}")
print("learned cost done — the MLP embedding absorbed the channel "
      "permutation the raw features could not.")
