"""GW-as-a-service demo: catalog matching through the solve server.

A catalog-matching workload — "score every incoming shape against a
reference shape" — is the serving layer's home turf: requests arrive
with diverse sizes (bucketed + batched into a handful of vmapped
executables) and the reference geometry recurs on every request (its
padded device artifact is served from the content-hash cache after the
first miss). Each request still gets its own health status, and an
unhealthy one falls back through the solver ladder without touching its
bucket-mates.

The legacy LM serving demo moved to examples/serve_lm_demo.py.

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
import numpy as np
import jax.numpy as jnp

import repro
from repro.serve import GWServer, ServeConfig


def make_shape(n, seed, twist=0.0):
    """A noisy spiral point set, as a distance-matrix Geometry."""
    rng = np.random.default_rng(seed)
    t = np.linspace(0, 3 * np.pi, n) + twist
    pts = np.stack([t * np.cos(t), t * np.sin(t)], 1)
    pts += 0.1 * rng.standard_normal(pts.shape)
    C = np.sqrt(((pts[:, None] - pts[None]) ** 2).sum(-1))
    return repro.Geometry(jnp.asarray(C, jnp.float32),
                          jnp.full(n, 1.0 / n, jnp.float32))


reference = make_shape(32, seed=0)

server = GWServer(ServeConfig(max_batch=8, max_wait_s=0.5))
solver = repro.get_solver("dense_gw").default_config(48)

# a stream of queries with diverse sizes; several recur (catalog regime)
sizes = [14, 20, 26, 14, 30, 20, 14, 26]
rids = [server.submit(
            repro.QuadraticProblem(make_shape(n, seed=i % 4, twist=0.3 * i),
                                   reference),
            solver)
        for i, n in enumerate(sizes)]

print("query -> GW distance to reference:")
for res in server.results(rids):
    print(f"  rid={res.rid} shape={res.shape} -> bucket{res.padded_shape} "
          f"value={res.value:.5f} status={res.status_name}"
          f"{' (fallback)' if res.fell_back else ''}")

stats = server.stats()
print(f"batches={stats['n_batches']} "
      f"mean_lanes={stats['mean_batch_lanes']:.1f} "
      f"cache_hit_rate={stats['cache_hit_rate']:.2f} "
      f"p50={stats['latency_p50_ms']:.0f}ms p99={stats['latency_p99_ms']:.0f}ms")
