"""Low-rank GW: linear-time couplings T = Q diag(1/g) Rᵀ (DESIGN.md §7).

Point-cloud geometries keep the squared-euclidean cost *implicit* — the
solver factors it exactly at rank d+2 and never materializes an n×n
matrix, so per-iteration cost is linear in n.

Run:  PYTHONPATH=src:. python examples/lowrank.py
"""
import sys
sys.path.insert(0, ".")

import time

import jax
import jax.numpy as jnp

import repro

key = jax.random.PRNGKey(0)

# -- small problem: low-rank tracks (and often beats) converged dense ------
n, d = 150, 2
kx, ky = jax.random.split(key)
x = jax.random.normal(kx, (n, d))
y = jax.random.normal(ky, (n, d)) * 1.2
a = b = jnp.ones(n) / n
problem = repro.QuadraticProblem(repro.Geometry.from_points(x, a),
                                 repro.Geometry.from_points(y, b))

dense_problem = repro.QuadraticProblem(
    repro.Geometry(problem.geom_x.cost_matrix, a),
    repro.Geometry(problem.geom_y.cost_matrix, b))
dense = repro.solve(dense_problem, repro.DenseGWSolver(
    outer_iters=60, inner_iters=2000, tol=1e-6, inner_tol=1e-8))
lr = repro.solve(problem, repro.LowRankGWSolver(rank=n // 2), key=key)
print(f"n={n}: dense PGA-GW = {float(dense.value):.5f}   "
      f"lowrank (r=n/2) = {float(lr.value):.5f}   "
      f"(mirror descent often finds the lower objective)")
mu, nu = lr.coupling.marginals()
print(f"        coupling storage (m+n)·r, marginal err = "
      f"{float(jnp.abs(mu - a).sum() + jnp.abs(nu - b).sum()):.2e}")

# -- large problem: the linear-time regime ---------------------------------
n = 10_000
kx, ky = jax.random.split(jax.random.PRNGKey(1))
x = jax.random.normal(kx, (n, 3))
y = jax.random.normal(ky, (n, 3))
a = b = jnp.ones((n,), jnp.float32) / n
problem = repro.QuadraticProblem(repro.Geometry.from_points(x, a),
                                 repro.Geometry.from_points(y, b))
# solver=None auto-selects lowrank_gw for factorizable point clouds
auto = repro.select_solver(problem)
print(f"n={n}: auto-selected solver = {type(auto).__name__}")
t0 = time.time()
out = repro.solve(problem, key=key)
print(f"        lowrank value = {float(out.value):.5f} in "
      f"{time.time() - t0:.1f}s (no n×n matrix was ever built)")

# -- nesting: low-rank coarse solve seeds the multiscale refinement --------
n = 1000
Cx = repro.Geometry.from_points(x[:n], jnp.ones(n) / n).cost_matrix
Cy = repro.Geometry.from_points(y[:n], jnp.ones(n) / n).cost_matrix
a = b = jnp.ones(n) / n
problem = repro.QuadraticProblem(repro.Geometry(Cx, a), repro.Geometry(Cy, b))
nested = repro.QuantizedGWSolver(base="lowrank_gw")
out = repro.solve(problem, nested, key=key)
print(f"n={n}: quantized_gw with a lowrank_gw coarse solve = "
      f"{float(out.value):.5f}")
