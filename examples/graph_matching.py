"""Graph analysis with SPAR-GW (paper §6.2): pairwise GW distances between
graphs -> similarity matrix -> spectral clustering.

Run:  PYTHONPATH=src python examples/graph_matching.py
"""
import sys
sys.path.insert(0, ".")

import itertools

import jax
import jax.numpy as jnp
import networkx as nx
import numpy as np

from benchmarks.bench_tables23_graphs import (
    graph_repr,
    make_corpus,
    rand_index,
    spectral_clustering,
)
import repro

graphs, labels = make_corpus(n_per_class=4, n_nodes=30)
reprs = [graph_repr(g) for g in graphs]
N = len(graphs)
print(f"{N} graphs, 3 families (SBM-2, SBM-3, Barabási–Albert)")

# One solver config reused across every pair; the problem carries the data.
solver = repro.SparGWSolver(s=8 * 30, epsilon=1e-2, outer_iters=8,
                            inner_iters=20, tol=1e-5)

D = np.zeros((N, N))
for i, j in itertools.combinations(range(N), 2):
    Ai, ai = reprs[i]
    Aj, aj = reprs[j]
    problem = repro.QuadraticProblem(repro.Geometry(Ai, ai),
                                     repro.Geometry(Aj, aj), loss="l1")
    out = repro.solve(problem, solver, key=jax.random.PRNGKey(i * N + j))
    D[i, j] = D[j, i] = max(float(out.value), 0.0)

gamma = np.median(D[D > 0])
S = np.exp(-D / gamma)
pred = spectral_clustering(S, 3)
print(f"Rand index vs true families: {rand_index(labels, pred):.3f}")
