"""Quickstart: approximate the GW distance between two point clouds with
SPAR-GW and compare against the dense PGA-GW benchmark.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "benchmarks")
sys.path.insert(0, ".")

import jax
import jax.numpy as jnp

from benchmarks.datasets import moon
from repro.core import grid_spar_gw, pga_gw, spar_gw

n = 150
a, b, Cx, Cy = moon(n)
a, b, Cx, Cy = map(jnp.asarray, (a, b, Cx, Cy))

print(f"Moon dataset, n={n}, Gaussian marginals (paper §6.1)")
for loss in ("l2", "l1"):
    ref, _ = pga_gw(a, b, Cx, Cy, loss=loss, epsilon=1e-2)
    est, _ = spar_gw(jax.random.PRNGKey(0), a, b, Cx, Cy, s=16 * n,
                     loss=loss, epsilon=1e-2)
    grid, _ = grid_spar_gw(jax.random.PRNGKey(0), a, b, Cx, Cy,
                           s_r=48, s_c=48, loss=loss, epsilon=1e-2)
    print(f"  {loss}: dense PGA-GW = {float(ref):.5f}   "
          f"SPAR-GW(s=16n) = {float(est):.5f}   "
          f"Grid-SPAR-GW = {float(grid):.5f}")
print("SPAR-GW touches O(n^2 + s^2) entries instead of O(n^4).")
