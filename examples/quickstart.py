"""Quickstart: approximate the GW distance between two point clouds with
SPAR-GW through the unified ``repro.solve`` API, and compare against the
dense PGA-GW benchmark.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "benchmarks")
sys.path.insert(0, ".")

import jax
import jax.numpy as jnp

import repro
from benchmarks.datasets import moon

n = 150
a, b, Cx, Cy = map(jnp.asarray, moon(n))
key = jax.random.PRNGKey(0)

print(f"Moon dataset, n={n}, Gaussian marginals (paper §6.1)")
print(f"registered solvers: {', '.join(repro.available_solvers())}")
# One problem object covers the whole variant family; solvers are configs.
for loss in ("l2", "l1"):
    problem = repro.QuadraticProblem(repro.Geometry(Cx, a),
                                     repro.Geometry(Cy, b), loss=loss)
    ref = repro.solve(problem, repro.DenseGWSolver(
        epsilon=1e-2, inner_iters=500, inner_tol=1e-6, tol=1e-5))
    est = repro.solve(problem, repro.SparGWSolver(
        s=16 * n, epsilon=1e-2, inner_iters=500, inner_tol=1e-6, tol=1e-5),
        key=key)
    grid = repro.solve(problem, repro.GridGWSolver(
        s_r=48, s_c=48, epsilon=1e-2, inner_iters=500, inner_tol=1e-6,
        tol=1e-5), key=key)
    print(f"  {loss}: dense PGA-GW = {float(ref.value):.5f} "
          f"({int(ref.n_iters)} outer iters, converged={bool(ref.converged)})"
          f"   SPAR-GW(s=16n) = {float(est.value):.5f}"
          f"   Grid-SPAR-GW = {float(grid.value):.5f}")

# Batched serving: one jit, a stack of problems, a batch of keys.
B = 4
keys = jax.random.split(key, B)
problem = repro.QuadraticProblem(repro.Geometry(Cx, a),
                                 repro.Geometry(Cy, b), loss="l2")
stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *([problem] * B))
batched = jax.jit(jax.vmap(lambda p, k: repro.solve(
    p, repro.SparGWSolver(s=8 * n, outer_iters=10), key=k)))
out = batched(stacked, keys)
print(f"vmap-batched SPAR-GW over {B} keys: "
      f"{[round(float(v), 5) for v in out.value]}")
print("SPAR-GW touches O(n^2 + s^2) entries instead of O(n^4).")
