"""End-to-end training driver: train an LM with the checkpointing /
fault-tolerance stack, optionally with the SPAR-GW representation-alignment
auxiliary loss (the paper's technique as a first-class training feature).

CPU demo (reduced config):
  PYTHONPATH=src python examples/train_lm.py
Full smollm-135m (the ~100M assignment config — sized for accelerators):
  PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""
import argparse

from repro.configs import base as cb
from repro.launch.train import train

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="smollm-135m")
ap.add_argument("--full", action="store_true")
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--gw-align", action="store_true")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

cfg = cb.get_arch(args.arch) if args.full else cb.get_reduced(args.arch)
params, opt, hist = train(
    cfg, args.steps, args.batch, args.seq, ckpt_dir=args.ckpt_dir,
    ckpt_every=50, gw_align=args.gw_align, base_lr=3e-3, log_every=20)
print(f"done: ce {hist[0]['ce']:.3f} -> {hist[-1]['ce']:.3f} "
      f"over {args.steps} steps (checkpoints in {args.ckpt_dir})")
