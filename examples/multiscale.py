"""Multiscale quantized GW: open the n >= 10k regime with anchor
compression (DESIGN.md §6), composing any registered base solver.

Run:  PYTHONPATH=src:. python examples/multiscale.py
"""
import sys
sys.path.insert(0, "benchmarks")
sys.path.insert(0, ".")

import time

import jax
import jax.numpy as jnp

import repro
from benchmarks.bench_multiscale import cloud_dists

key = jax.random.PRNGKey(0)

# -- small problem: quantized tracks a converged dense solve ---------------
n = 150
Cx = jnp.asarray(cloud_dists(0, n))
Cy = jnp.asarray(cloud_dists(1, n))
a = b = jnp.ones(n) / n
problem = repro.QuadraticProblem(repro.Geometry(Cx, a), repro.Geometry(Cy, b))

dense = repro.solve(problem, repro.DenseGWSolver(
    outer_iters=60, inner_iters=2000, tol=1e-6, inner_tol=1e-8))
quant = repro.solve(problem, repro.QuantizedGWSolver(k_x=n // 2, k_y=n // 2),
                    key=key)
print(f"n={n}: dense PGA-GW = {float(dense.value):.5f}   "
      f"quantized (k=n/2, polished) = {float(quant.value):.5f}   "
      f"rel err = {abs(float(quant.value) - float(dense.value)) / float(dense.value):.2%}")

# the coarse stage composes with any registered solver
spar_base = repro.QuantizedGWSolver(
    k_x=n // 2, k_y=n // 2,
    base=repro.SparGWSolver(tol=1e-6, inner_tol=1e-8))   # s auto-sized
out = repro.solve(problem, spar_base, key=key)
print(f"        quantized with spar_gw anchor solve = {float(out.value):.5f}")

# -- large problem: the regime dense cannot touch --------------------------
n = 4000
Cx = jnp.asarray(cloud_dists(0, n))
Cy = jnp.asarray(cloud_dists(1, n))
a = b = jnp.ones((n,), jnp.float32) / n
problem = repro.QuadraticProblem(repro.Geometry(Cx, a), repro.Geometry(Cy, b))
# solver=None auto-selects quantized_gw above n=2048 (repro.select_solver)
auto = repro.select_solver(problem)
print(f"n={n}: auto-selected solver = {type(auto).__name__}")
t0 = time.time()
out = repro.solve(problem, key=key)
value = float(out.value)
print(f"        quantized value = {value:.5f} "
      f"(coarse estimate, k≈√n anchors) in {time.time() - t0:.1f}s")
mu, nu = out.coupling.marginals(n, n)
print(f"        refined coupling marginal error = "
      f"{float(jnp.abs(mu - a).sum() + jnp.abs(nu - b).sum()):.3f}")
