"""LM serving demo: batched generation with a KV cache + GW-distance
scoring between request batches (structural similarity of hidden
geometries). The GW solve-server demo lives in examples/serve_demo.py.

Run:  PYTHONPATH=src python examples/serve_lm_demo.py
"""
import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.launch.serve import generate, gw_similarity
from repro.models import build_model

cfg = cb.get_reduced("llama3-8b")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

prompts = jax.random.randint(jax.random.PRNGKey(7), (4, 24), 0,
                             cfg.vocab_size)
seqs = generate(model, params, prompts, max_new=16)
print("generated:", seqs.shape)

other = jax.random.randint(jax.random.PRNGKey(8), (4, 24), 0, cfg.vocab_size)
print("GW(batch, itself)    =",
      float(gw_similarity(model, params, prompts, prompts, s=24)))
print("GW(batch, other)     =",
      float(gw_similarity(model, params, prompts, other, s=24)))
