"""Parity (interpret mode) + regression tests for the fused spar_cost
kernel family, and for the unified kernels/dispatch.py layer."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spar_gw import spar_gw
from repro.kernels import dispatch
from repro.kernels.spar_cost.ops import (
    make_spar_cost_fn,
    resolve_impl,
    spar_cost_fused,
    spar_matvec,
)
from repro.kernels.spar_cost.ref import materialize_loss, spar_cost_ref

KEY = jax.random.PRNGKey(0)


def _support(m, n, s, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    Cx = jax.random.uniform(ks[0], (m, m)) + 0.1        # >0 so kl is finite
    Cy = jax.random.uniform(ks[1], (n, n)) + 0.1
    rows = jax.random.randint(ks[2], (s,), 0, m)
    cols = jax.random.randint(ks[3], (s,), 0, n)
    t = jax.random.uniform(ks[4], (s,))
    return Cx, Cy, rows, cols, t


# ---------------------------------------------------------------------------
# kernel parity vs the jnp lax.map oracle (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("loss", ["l1", "l2", "kl"])
@pytest.mark.parametrize("s", [64, 96, 100, 33])   # incl. non-block-multiples
def test_fused_kernel_matches_oracle(loss, s):
    Cx, Cy, rows, cols, t = _support(50, 60, s, seed=s)
    ref = spar_cost_ref(Cx, Cy, rows, cols, t, loss, chunk=32)
    got = spar_cost_fused(Cx, Cy, rows, cols, t, loss=loss, block=32,
                          interpret=True)
    np.testing.assert_allclose(np.array(got), np.array(ref), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("loss", ["l1", "l2", "kl"])
def test_materialized_matvec_matches_oracle(loss):
    s = 100
    Cx, Cy, rows, cols, t = _support(40, 40, s, seed=7)
    ref = spar_cost_ref(Cx, Cy, rows, cols, t, loss, chunk=64)
    Lmat = materialize_loss(Cx, Cy, rows, cols, loss, chunk=64)
    got = spar_matvec(Lmat, t, block=32, interpret=True)
    np.testing.assert_allclose(np.array(got), np.array(ref), rtol=1e-4,
                               atol=1e-5)


def test_duplicate_pairs_are_parallel_entries():
    """Duplicate (row, col) draws are legitimate parallel COO entries —
    every impl must treat them independently (gather semantics)."""
    s = 64
    Cx, Cy, _, _, t = _support(30, 30, s, seed=3)
    rows = jnp.zeros((s,), jnp.int32).at[1:].set(
        jax.random.randint(KEY, (s - 1,), 0, 30))
    cols = rows[::-1]                                   # forced duplicates
    rows = rows.at[10:20].set(rows[0])                  # repeated pairs
    cols = cols.at[10:20].set(cols[0])
    for loss in ("l1", "l2"):
        ref = spar_cost_ref(Cx, Cy, rows, cols, t, loss, chunk=16)
        got = spar_cost_fused(Cx, Cy, rows, cols, t, loss=loss, block=16,
                              interpret=True)
        np.testing.assert_allclose(np.array(got), np.array(ref), rtol=1e-4,
                                   atol=1e-5)


def test_affine_epilogue_offset():
    """out = L @ t + off — the epilogue that forms logK on-chip."""
    s = 96
    Cx, Cy, rows, cols, t = _support(25, 35, s, seed=11)
    off = jax.random.normal(jax.random.PRNGKey(12), (s,))
    ref = spar_cost_ref(Cx, Cy, rows, cols, t, "l2", chunk=32) + off
    got = spar_cost_fused(Cx, Cy, rows, cols, t, off, loss="l2", block=32,
                          interpret=True)
    np.testing.assert_allclose(np.array(got), np.array(ref), rtol=1e-4,
                               atol=1e-5)
    Lmat = materialize_loss(Cx, Cy, rows, cols, "l2", chunk=32)
    got2 = spar_matvec(Lmat, t, off, block=32, interpret=True)
    np.testing.assert_allclose(np.array(got2), np.array(ref), rtol=1e-4,
                               atol=1e-5)


def test_make_spar_cost_fn_impls_agree():
    s = 80
    Cx, Cy, rows, cols, t = _support(30, 45, s, seed=5)
    off = jnp.linspace(-1.0, 1.0, s)
    outs = {}
    for impl in ("jnp", "pallas", "materialized"):
        fn = make_spar_cost_fn(Cx, Cy, rows, cols, "l2", impl=impl,
                               chunk=32, block=16)
        outs[impl] = np.array(fn(t, off))
    np.testing.assert_allclose(outs["pallas"], outs["jnp"], rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(outs["materialized"], outs["jnp"], rtol=1e-5,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# solver-level regression: cost_impl must not change the estimate
# ---------------------------------------------------------------------------

def test_spar_gw_pallas_and_materialized_match():
    n = 32
    x = jax.random.normal(KEY, (n, 2))
    Cx = jnp.sqrt(jnp.sum((x[:, None] - x[None, :]) ** 2, -1))
    y = jax.random.normal(jax.random.PRNGKey(1), (n, 2)) * 1.3
    Cy = jnp.sqrt(jnp.sum((y[:, None] - y[None, :]) ** 2, -1))
    a = b = jnp.ones(n) / n
    kw = dict(s=8 * n, loss="l2", epsilon=1e-2, outer_iters=5,
              inner_iters=20)
    key = jax.random.PRNGKey(42)
    v_jnp, (_, _, T_jnp) = spar_gw(key, a, b, Cx, Cy, cost_impl="jnp", **kw)
    v_pal, (_, _, T_pal) = spar_gw(key, a, b, Cx, Cy, cost_impl="pallas",
                                   **kw)
    v_mat, (_, _, T_mat) = spar_gw(key, a, b, Cx, Cy,
                                   cost_impl="materialized", **kw)
    np.testing.assert_allclose(float(v_pal), float(v_jnp), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(float(v_mat), float(v_jnp), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(np.array(T_pal), np.array(T_mat), rtol=1e-4,
                               atol=1e-7)


# ---------------------------------------------------------------------------
# dispatch layer
# ---------------------------------------------------------------------------

def test_no_import_time_interpret_globals():
    """Acceptance: no per-ops.py _INTERPRET globals remain — backend is
    resolved at call time inside kernels/dispatch.py."""
    for mod in ("repro.kernels.gw_cost.ops", "repro.kernels.sinkhorn.ops",
                "repro.kernels.flash_attention.ops", "repro.kernels.ssd.ops",
                "repro.kernels.spar_cost.ops"):
        assert not hasattr(importlib.import_module(mod), "_INTERPRET"), mod


def test_interpret_mode_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert dispatch.interpret_mode() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert dispatch.interpret_mode() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "auto")
    assert dispatch.interpret_mode() == (jax.default_backend() != "tpu")
    # explicit override beats the env
    assert dispatch.interpret_mode(True) is True


def test_block_size_resolution_order(monkeypatch):
    dispatch.register("_test_family", default_block=64)
    assert dispatch.block_size("_test_family") == 64
    monkeypatch.setenv("REPRO_BLOCK__TEST_FAMILY", "16")
    assert dispatch.block_size("_test_family") == 16
    assert dispatch.block_size("_test_family", override=8) == 8
    assert dispatch.block_size("_test_family", cap=4) == 4


def test_autotune_caches_best_block(monkeypatch):
    dispatch.register("_test_tune", default_block=128)
    calls = []

    def bench(block):
        calls.append(block)
        if block == 32:
            import time
            time.sleep(0.002)
        return jnp.zeros(())

    best = dispatch.autotune("_test_tune", [8, 32], bench, reps=1)
    assert best == 8
    monkeypatch.delenv("REPRO_BLOCK__TEST_TUNE", raising=False)
    assert dispatch.block_size("_test_tune") == 8
    recs = [r for r in dispatch.autotune_records()
            if r["family"] == "_test_tune"]
    assert recs and recs[-1]["best_block"] == 8


def test_pad_unpad_roundtrip():
    x = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
    xp, shape = dispatch.pad_to_multiple(x, (8, 128))
    assert xp.shape == (8, 128)
    np.testing.assert_array_equal(np.array(dispatch.unpad(xp, shape)),
                                  np.array(x))


def test_resolve_impl_auto_gate(monkeypatch):
    monkeypatch.setenv("REPRO_SPAR_MATERIALIZE_BUDGET", str(4 * 100 * 100))
    assert resolve_impl("auto", 100) == "materialized"
    assert resolve_impl("auto", 101) in ("pallas", "jnp")
    assert resolve_impl("jnp", 10**9) == "jnp"
