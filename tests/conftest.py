import subprocess
import sys
import textwrap

import pytest


def run_with_devices(code: str, n_devices: int = 4, timeout: int = 420):
    """Run a snippet in a subprocess with N forced host devices (the main
    process is locked to 1 device once jax initializes)."""
    env = {"PYTHONPATH": "src",
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_devices}",
           "PATH": "/usr/bin:/bin"}
    import os
    env["PATH"] = os.environ.get("PATH", env["PATH"])
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env={**os.environ, **env}, cwd=".")
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}")
    return res.stdout


@pytest.fixture
def multi_device_runner():
    return run_with_devices
