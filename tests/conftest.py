import importlib.util
import subprocess
import sys
import textwrap

import pytest

# Optional-dependency guard: test modules must NOT hard-import optional
# packages (a ModuleNotFoundError at collection aborts the whole suite).
# Instead they guard the import with try/except and mark dependent tests
# with @pytest.mark.optional_dep("<package>"); this hook skips them when
# the package is missing. Dev installs get everything: requirements-dev.txt.
_OPTIONAL_DEPS = ("hypothesis",)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "optional_dep(name): test requires an optional dev dependency; "
        "skipped (not errored) when the package is not installed.")


def pytest_collection_modifyitems(config, items):
    missing = {name for name in _OPTIONAL_DEPS
               if importlib.util.find_spec(name) is None}
    if not missing:
        return
    for item in items:
        marker = item.get_closest_marker("optional_dep")
        if marker and marker.args and marker.args[0] in missing:
            item.add_marker(pytest.mark.skip(
                reason=f"optional dependency {marker.args[0]!r} "
                       f"not installed (see requirements-dev.txt)"))


def run_with_devices(code: str, n_devices: int = 4, timeout: int = 420):
    """Run a snippet in a subprocess with N forced host devices (the main
    process is locked to 1 device once jax initializes)."""
    env = {"PYTHONPATH": "src",
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_devices}",
           "PATH": "/usr/bin:/bin"}
    import os
    env["PATH"] = os.environ.get("PATH", env["PATH"])
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env={**os.environ, **env}, cwd=".")
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}")
    return res.stdout


@pytest.fixture
def multi_device_runner():
    return run_with_devices
