"""GW core: solver correctness, SPAR estimators, paper-claim validations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _optional import given, settings, st  # guarded hypothesis import

import repro
from repro import DenseGWSolver, Geometry, QuadraticProblem, SparGWSolver
from repro.core import (
    dense_cost,
    egw,
    grid_spar_gw,
    gw_objective,
    pga_gw,
    sagrow,
    spar_fgw,
    spar_gw,
    spar_ugw,
    ugw_dense,
)
from repro.core import ground_cost as gc
from repro.core import sampling
from repro.core.spar_gw import spar_cost

KEY = jax.random.PRNGKey(0)


def _cloud(key, n, d=2, scale=1.0, shift=0.0):
    x = jax.random.normal(key, (n, d)) * scale + shift
    C = jnp.sqrt(jnp.sum((x[:, None] - x[None, :]) ** 2, -1))
    return C


def _gauss_weights(n, mean_frac=0.4, std_frac=0.06):
    """Concentrated marginals (paper's Moon setup: N(n/3, n/20))."""
    idx = np.arange(n)
    w = np.exp(-0.5 * ((idx - mean_frac * n) / (std_frac * n + 1)) ** 2)
    w = w + 1e-6
    return jnp.asarray(w / w.sum(), jnp.float32)


# ---------------------------------------------------------------------------
# dense cost assembly
# ---------------------------------------------------------------------------

def test_dense_cost_decomposable_matches_general():
    """The Peyré decomposition must equal the O(n^4) direct contraction."""
    m, n = 10, 12
    Cx = _cloud(KEY, m)
    Cy = _cloud(jax.random.PRNGKey(1), n)
    T = jax.random.uniform(jax.random.PRNGKey(2), (m, n))
    T = T / T.sum()
    for loss in ("l2", "kl"):
        L = gc.get_loss(loss)
        direct = jnp.einsum(
            "ik,jl,kl->ij",
            jnp.ones((m, m)), jnp.ones((n, n)), T) * 0  # shape helper
        E = L(Cx[:, :, None, None] + 1e-3, Cy[None, None, :, :] + 1e-3)
        direct = jnp.einsum("abcd,bd->ac", E, T)
        fast = dense_cost(Cx + 1e-3, Cy + 1e-3, T, loss)
        np.testing.assert_allclose(np.array(fast), np.array(direct),
                                   rtol=1e-4, atol=1e-5)


def test_spar_cost_matches_dense_on_support():
    m = n = 16
    Cx, Cy = _cloud(KEY, m), _cloud(jax.random.PRNGKey(1), n)
    rows = jnp.arange(m).repeat(n) % m
    rows, cols = jnp.meshgrid(jnp.arange(m), jnp.arange(n), indexing="ij")
    rows, cols = rows.reshape(-1), cols.reshape(-1)
    tvals = jax.random.uniform(jax.random.PRNGKey(2), (m * n,)) / (m * n)
    T = jnp.zeros((m, n)).at[rows, cols].set(tvals)
    dense = dense_cost(Cx, Cy, T, "l1")
    sparse = spar_cost(Cx, Cy, rows, cols, tvals, "l1", chunk=64)
    np.testing.assert_allclose(np.array(dense[rows, cols]), np.array(sparse),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# estimator behaviour (paper claims)
# ---------------------------------------------------------------------------

def test_gw_self_distance_near_zero():
    """GW((C,a),(C,a)) = 0; PGA should find (near) zero.

    Historically failed: at ε=1e-3 the inner Sinkhorn projection needs
    ~300 iterations, so any fixed budget ≤ ~100 leaves an ℓ1 marginal
    violation of ~0.3 and the outer PGA loop stalls at a non-coupling
    fixed point (more outer iterations don't help). The tolerance-aware
    inner loop (``inner_tol``) converges the projection, and the outer
    early stop finishes in a handful of iterations.
    """
    n = 24
    C = _cloud(KEY, n)
    a = jnp.ones(n) / n
    problem = QuadraticProblem(Geometry(C, a), Geometry(C, a), loss="l2")
    out = repro.solve(problem, DenseGWSolver(
        reg="prox", epsilon=1e-3, outer_iters=50, inner_iters=500,
        tol=1e-6, inner_tol=1e-7))
    naive = gw_objective(C, C, a[:, None] * a[None, :], "l2")
    assert bool(out.converged), np.asarray(out.errors)
    assert float(out.value) < 0.15 * float(naive)


def test_spar_gw_approaches_dense_with_full_sampling():
    """With s large and concentrated marginals the SPAR estimate must land
    near the dense PGA benchmark (paper Fig. 2 Moon behaviour).

    Historically failed for the same root cause as the self-distance
    test: the concentrated Gaussian marginals (weights down to ~1e-6)
    make the fixed 50-iteration Sinkhorn budget wildly unconverged
    (ℓ1 marginal violation ≈ 0.5 dense / 1.0 sparse), so both estimates
    were garbage. With tolerance-driven inner loops both solvers produce
    actual couplings and the estimates agree.
    """
    n = 48
    Cx = _cloud(KEY, n)
    Cy = _cloud(jax.random.PRNGKey(1), n, scale=1.2, shift=1.0)
    a = _gauss_weights(n, 0.33, 0.05)
    b = _gauss_weights(n, 0.5, 0.05)
    problem = QuadraticProblem(Geometry(Cx, a), Geometry(Cy, b), loss="l2")
    ref = repro.solve(problem, DenseGWSolver(
        epsilon=1e-2, inner_iters=1000, inner_tol=1e-6))
    # dense marginal projection actually converged this time
    assert float(ref.errors[int(ref.n_iters) - 1]) < 0.1
    solver = SparGWSolver(s=32 * n, epsilon=1e-2, inner_iters=1000,
                          inner_tol=1e-6)
    vals = [float(repro.solve(problem, solver,
                              key=jax.random.PRNGKey(seed)).value)
            for seed in range(4)]
    err = abs(np.mean(vals) - float(ref.value))
    assert err < 0.5 * max(abs(float(ref.value)), 0.05), \
        (np.mean(vals), float(ref.value))


def test_grid_and_coo_agree():
    n = 40
    Cx = _cloud(KEY, n)
    Cy = _cloud(jax.random.PRNGKey(1), n, scale=1.3)
    a = _gauss_weights(n)
    b = _gauss_weights(n, 0.55)
    v_coo = np.mean([float(spar_gw(jax.random.PRNGKey(s), a, b, Cx, Cy,
                                   s=1024, loss="l2")[0]) for s in range(3)])
    v_grid = np.mean([float(grid_spar_gw(jax.random.PRNGKey(s), a, b, Cx, Cy,
                                         s_r=32, s_c=32, loss="l2")[0])
                      for s in range(3)])
    assert abs(v_coo - v_grid) < 0.5 * max(abs(v_coo), abs(v_grid), 0.05)


def test_sampling_probs_factorize_and_normalize():
    a = _gauss_weights(30)
    b = _gauss_weights(22, 0.6)
    probs = sampling.balanced_probs(a, b)
    # eq (5): p_ij = sqrt(a_i b_j)/Z == pa_i * pb_j
    P = jnp.sqrt(a[:, None] * b[None, :])
    P = P / P.sum()
    P_fact = probs.pa[:, None] * probs.pb[None, :]
    np.testing.assert_allclose(np.array(P), np.array(P_fact), rtol=1e-5)


def test_poisson_sampling_unbiased():
    """Appendix B: E[K̃] = K under Poisson subsampling."""
    key = KEY
    n = 12
    K = jax.random.uniform(key, (n, n)) + 0.1
    probs = jnp.ones((n * n,)) / (n * n)
    s = 60
    acc = jnp.zeros((n * n,))
    reps = 400
    for i in range(reps):
        mask, p_star = sampling.poisson_mask(jax.random.PRNGKey(i),
                                             probs, s)
        acc = acc + jnp.where(mask, K.reshape(-1) / p_star, 0.0)
    est = np.array(acc / reps)
    np.testing.assert_allclose(est, np.array(K.reshape(-1)), rtol=0.25)


def test_fgw_interpolates():
    """alpha→1 recovers GW; alpha→0 recovers the Wasserstein-like cost."""
    n = 24
    Cx = _cloud(KEY, n)
    Cy = _cloud(jax.random.PRNGKey(1), n)
    M = jax.random.uniform(jax.random.PRNGKey(2), (n, n))
    a = b = jnp.ones(n) / n
    key = jax.random.PRNGKey(3)
    v_gw, _ = spar_gw(key, a, b, Cx, Cy, s=16 * n, loss="l2")
    v_a1, _ = spar_fgw(key, a, b, Cx, Cy, M, s=16 * n, alpha=0.999,
                       loss="l2")
    assert abs(float(v_a1) - float(v_gw)) < 0.2 * max(abs(float(v_gw)), 0.02)


def test_ugw_finite_and_reasonable():
    n = 30
    Cx = _cloud(KEY, n)
    Cy = _cloud(jax.random.PRNGKey(1), n, scale=1.5)
    a = jnp.ones(n) / n
    b = jnp.ones(n) / n * 1.3          # unbalanced masses
    v_dense, T = ugw_dense(a, b, Cx, Cy, lam=1.0, epsilon=1e-2)
    v_spar, _ = spar_ugw(KEY, a, b, Cx, Cy, s=16 * n, lam=1.0, epsilon=1e-2)
    assert np.isfinite(float(v_dense)) and np.isfinite(float(v_spar))
    assert float(v_spar) >= -1e-6
    naive = float(ugw_dense(a, b, Cx, Cy, lam=1.0, epsilon=1e-2,
                            outer_iters=0)[0]) if False else None
    # spar estimate within a factor-2 band of the dense solver
    assert abs(float(v_spar) - float(v_dense)) < \
        1.0 * max(abs(float(v_dense)), 0.05)


@pytest.mark.optional_dep("hypothesis")
@settings(max_examples=8, deadline=None)
@given(st.integers(0, 100))
def test_property_spar_gw_nonnegative_l2(seed):
    n = 20
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    Cx, Cy = _cloud(k1, n), _cloud(k2, n)
    a = b = jnp.ones(n) / n
    v, (_, _, T) = spar_gw(jax.random.PRNGKey(seed), a, b, Cx, Cy, s=8 * n,
                           loss="l2", outer_iters=5, inner_iters=20)
    assert float(v) >= -1e-6
    assert np.array(T).min() >= -1e-9
    assert abs(float(jnp.sum(T)) - 1.0) < 0.2   # near-coupling mass


def test_grid_gw_kernel_path_matches_jnp():
    """grid_spar_gw(use_kernel=True) routes cost assembly through the
    Pallas gw_cost kernel (interpret mode on CPU) — same estimate."""
    n = 32
    Cx = _cloud(KEY, n)
    Cy = _cloud(jax.random.PRNGKey(1), n)
    a = b = jnp.ones(n) / n
    kw = dict(s_r=32, s_c=32, loss="l1", epsilon=5e-2, outer_iters=3,
              inner_iters=10)
    v_ref, _ = grid_spar_gw(jax.random.PRNGKey(0), a, b, Cx, Cy,
                            use_kernel=False, **kw)
    v_ker, _ = grid_spar_gw(jax.random.PRNGKey(0), a, b, Cx, Cy,
                            use_kernel=True, **kw)
    assert abs(float(v_ref) - float(v_ker)) < 1e-3


def test_regularizer_choice_yields_similar_results():
    """Paper §6.1: 'The other choice of regularization term yields similar
    results' — prox (KL proximal) vs ent (entropic) SPAR-GW."""
    n = 48
    Cx = _cloud(KEY, n)
    Cy = _cloud(jax.random.PRNGKey(1), n, scale=1.2)
    a = _gauss_weights(n, 0.33, 0.05)
    b = _gauss_weights(n, 0.5, 0.05)
    v_prox = np.mean([float(spar_gw(jax.random.PRNGKey(s), a, b, Cx, Cy,
                                    s=16 * n, loss="l2", reg="prox")[0])
                      for s in range(3)])
    v_ent = np.mean([float(spar_gw(jax.random.PRNGKey(s), a, b, Cx, Cy,
                                   s=16 * n, loss="l2", reg="ent")[0])
                     for s in range(3)])
    assert abs(v_prox - v_ent) < 0.5 * max(abs(v_prox), 0.05), (v_prox, v_ent)


def test_ugw_degenerates_to_gw_at_large_lambda():
    """Paper §5.1: with unit masses, UGW -> GW as λ -> ∞. With a fixed
    inner-iteration budget the residual penalty λ·KL⊗ cannot fully vanish
    (the scaling exponent ρ = λ̄/(λ̄+ε̄) -> 1 slows Sinkhorn), so we check
    the *coupling*: total mass -> 1 and the transport (quadratic) part of
    the objective approaches the balanced GW value."""
    n = 24
    Cx = _cloud(KEY, n)
    Cy = _cloud(jax.random.PRNGKey(1), n, scale=1.2)
    a = b = jnp.ones(n) / n
    v_gw, _ = pga_gw(a, b, Cx, Cy, loss="l2", epsilon=1e-2, outer_iters=10,
                     inner_iters=40)
    _, T = ugw_dense(a, b, Cx, Cy, loss="l2", lam=100.0, epsilon=1e-2,
                     outer_iters=10, inner_iters=40)
    mass = float(jnp.sum(T))
    assert abs(mass - 1.0) < 0.01, mass
    quad = float(gw_objective(Cx, Cy, T, "l2"))
    assert abs(quad - float(v_gw)) < 0.5 * max(abs(float(v_gw)), 0.02), \
        (quad, float(v_gw))
    # and mass deviation should shrink with λ (degeneration direction)
    _, T1 = ugw_dense(a, b, Cx, Cy, loss="l2", lam=1.0, epsilon=1e-2,
                      outer_iters=10, inner_iters=40)
    assert abs(float(jnp.sum(T1)) - 1.0) > abs(mass - 1.0)
