"""End-to-end behaviour tests for the system: training reduces loss, the GW
engine approximates its dense benchmark end-to-end, and serving generates."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cb


def test_training_reduces_loss():
    from repro.launch.train import train
    cfg = cb.get_reduced("smollm_135m")
    _, _, hist = train(cfg, 60, 8, 64, ckpt_dir=None, log_every=0,
                       base_lr=3e-3)
    first = np.mean([h["ce"] for h in hist[:5]])
    last = np.mean([h["ce"] for h in hist[-5:]])
    assert last < first - 0.2, (first, last)


def test_serve_generates_and_scores():
    from repro.launch.serve import generate, gw_similarity
    from repro.models import build_model
    cfg = cb.get_reduced("llama3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    seqs = generate(model, params, prompts, max_new=4)
    assert seqs.shape == (2, 12)
    sim_self = gw_similarity(model, params, prompts, prompts, s=16)
    assert np.isfinite(float(sim_self))


def test_spar_gw_pipeline_on_graph_data():
    """The paper's Graph workload shape: adjacency relation matrices +
    degree-distribution marginals, l1 cost."""
    import networkx as nx
    from repro.core import pga_gw, spar_gw
    g1 = nx.barabasi_albert_graph(40, 3, seed=1)
    g2 = nx.barabasi_albert_graph(40, 3, seed=2)
    C1 = jnp.asarray(nx.to_numpy_array(g1), jnp.float32)
    C2 = jnp.asarray(nx.to_numpy_array(g2), jnp.float32)
    d1 = C1.sum(1); a = d1 / d1.sum()
    d2 = C2.sum(1); b = d2 / d2.sum()
    ref, _ = pga_gw(a, b, C1, C2, loss="l1", epsilon=1e-2)
    est, _ = spar_gw(jax.random.PRNGKey(0), a, b, C1, C2, s=16 * 40,
                     loss="l1", epsilon=1e-2)
    assert np.isfinite(float(est))
    assert abs(float(est) - float(ref)) < max(1.0 * abs(float(ref)), 0.05)
