"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gw_cost.ops import gw_cost
from repro.kernels.gw_cost.ref import gw_cost_ref
from repro.kernels.sinkhorn.ops import sinkhorn as sinkhorn_kernel
from repro.kernels.sinkhorn.ref import sinkhorn_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("loss", ["l1", "l2", "kl"])
@pytest.mark.parametrize("shape", [(32, 32, 32, 32), (64, 48, 40, 56),
                                   (33, 17, 65, 9), (128, 96, 64, 80)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gw_cost_sweep(loss, shape, dtype):
    K, L, M, P = shape
    k1, k2, k3 = jax.random.split(KEY, 3)
    A = (jax.random.uniform(k1, (K, L)) + 0.1).astype(dtype)
    B = (jax.random.uniform(k2, (M, P)) + 0.1).astype(dtype)
    T = jax.random.uniform(k3, (L, P)).astype(dtype)
    got = gw_cost(A, B, T, loss)
    ref = gw_cost_ref(A.astype(jnp.float32), B.astype(jnp.float32),
                      T.astype(jnp.float32), loss)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.array(got), np.array(ref), rtol=tol,
                               atol=tol)


@pytest.mark.parametrize("shape", [(2, 128, 4, 2, 32), (1, 256, 8, 8, 64),
                                   (2, 64, 6, 3, 16), (1, 512, 2, 1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(shape, dtype):
    B, S, H, K, hd = shape
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, S, H, hd)).astype(dtype)
    k = jax.random.normal(k2, (B, S, K, hd)).astype(dtype)
    v = jax.random.normal(k3, (B, S, K, hd)).astype(dtype)
    got = flash_attention(q, k, v)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32))
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.array(got, np.float32), np.array(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("mn", [(64, 48), (128, 128), (96, 32)])
@pytest.mark.parametrize("iters", [10, 50])
def test_sinkhorn_kernel_sweep(mn, iters):
    m, n = mn
    k1 = jax.random.PRNGKey(m * n + iters)
    a = jnp.ones(m) / m
    b = jnp.ones(n) / n
    K = jax.random.uniform(k1, (m, n)) + 0.01
    got = sinkhorn_kernel(a, b, K, iters=iters)
    ref = sinkhorn_ref(a, b, K, iters)
    np.testing.assert_allclose(np.array(got), np.array(ref), rtol=1e-4,
                               atol=1e-8)


def test_sinkhorn_kernel_fallback_above_vmem_budget():
    m = n = 2048                      # 16 MiB f32 > 8 MiB budget -> jnp path
    a = jnp.ones(m) / m
    b = jnp.ones(n) / n
    K = jax.random.uniform(KEY, (m, n)) + 0.01
    T = sinkhorn_kernel(a, b, K, iters=3)
    assert np.isfinite(np.array(T)).all()


@pytest.mark.parametrize("shape", [(2, 32, 8, 16, 8), (3, 64, 4, 32, 16),
                                   (1, 16, 6, 8, 4)])
def test_ssd_intra_kernel_sweep(shape):
    """Mamba2 SSD intra-chunk kernel vs oracle (grid over batch*chunks and
    head tiles)."""
    from repro.kernels.ssd.ops import ssd_intra
    from repro.kernels.ssd.ref import ssd_intra_ref
    G, k, H, P, N = shape
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    xdt = jax.random.normal(k1, (G, k, H, P))
    cs = -jax.random.uniform(k2, (G, k, H)).cumsum(axis=1)   # decaying
    Bm = jax.random.normal(k3, (G, k, N))
    Cm = jax.random.normal(k4, (G, k, N))
    got = ssd_intra(xdt, cs, Bm, Cm)
    ref = jax.vmap(ssd_intra_ref)(xdt, cs, Bm, Cm)
    np.testing.assert_allclose(np.array(got), np.array(ref), rtol=1e-4,
                               atol=1e-4)
