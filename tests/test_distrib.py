"""Multi-device distribution tests (subprocess with forced host devices):
sharded GW vs reference, pipeline parallelism, gradient compression, and a
sharded train step."""
import numpy as np
import pytest

from repro.distrib.compression import dequantize_int8, quantize_int8


def test_int8_quantization_error_bound():
    import jax, jax.numpy as jnp
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s, x.shape)
    err = np.max(np.abs(np.array(back) - np.array(x)))
    # block max / 127 bound
    bound = float(jnp.max(jnp.abs(x))) / 127.0 * 1.01
    assert err <= bound


def test_sharded_gw_matches_reference(multi_device_runner):
    multi_device_runner("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.sharded_gw import make_sharded_grid_gw
from repro.core.grid_gw import grid_cost
from repro.core.sinkhorn import sinkhorn_log
mesh = jax.make_mesh((2,2), ("data","model"))
s_r = s_c = 16
key = jax.random.PRNGKey(0)
CxR = jax.random.uniform(key,(s_r,s_r)); CxR=(CxR+CxR.T)/2
CyC = jax.random.uniform(jax.random.PRNGKey(1),(s_c,s_c)); CyC=(CyC+CyC.T)/2
aR = jnp.ones(s_r)/s_r; bC = jnp.ones(s_c)/s_c; w = jnp.ones((s_r,s_c))
solver = make_sharded_grid_gw(mesh, s_r, s_c, "l2", 0.05, 4, 15)
with mesh:
    val, T = solver(CxR, CyC, aR, bC, w)
Tr = aR[:,None]*bC[None,:]
for _ in range(4):
    C = grid_cost(CxR, CyC, Tr, "l2")
    Tr = sinkhorn_log(aR, bC, -C/0.05 + jnp.log(w) + jnp.log(jnp.maximum(Tr,1e-38)), 15)
ref = float(jnp.sum(Tr*grid_cost(CxR,CyC,Tr,"l2")))
assert abs(float(val)-ref) < 1e-4, (float(val), ref)
print("ok")
""")


def test_compressed_psum_under_shard_map(multi_device_runner):
    multi_device_runner("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.distrib.compression import dp_allreduce_grads
mesh = jax.make_mesh((4,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
def f(x_local):
    g = {"w": x_local[0]}
    out = dp_allreduce_grads(g, "data", compress=True)
    return out["w"]
y = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P(), check_rep=False)(x)
ref = np.mean(np.array(x), axis=0)
err = np.max(np.abs(np.array(y) - ref))
bound = np.abs(np.array(x)).max()/127.0*1.5 + 1e-6
assert err < bound, (err, bound)
print("ok")
""")


def test_pipeline_parallel_matches_sequential(multi_device_runner):
    multi_device_runner("""
import jax, jax.numpy as jnp, numpy as np
from repro.distrib.pipeline import pipeline_forward
mesh = jax.make_mesh((4,), ("pipe",))
n_stages, n_micro, mb, d = 4, 8, 2, 16
key = jax.random.PRNGKey(0)
Ws = jax.random.normal(key, (n_stages, d, d)) * 0.3
def stage_fn(W, x):
    return jnp.tanh(x @ W)
x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
piped = pipeline_forward(mesh, stage_fn, n_stages, n_micro)
with mesh:
    y = piped(Ws, x)
# sequential reference
ref = x
for i in range(n_stages):
    ref = jnp.tanh(ref @ Ws[i])
np.testing.assert_allclose(np.array(y), np.array(ref), atol=1e-5)
print("ok")
""")


def test_sharded_train_step_runs(multi_device_runner):
    multi_device_runner("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import base as cb
from repro.launch.steps import make_train_step
from repro.models.model_zoo import Model, set_activation_sharding
from repro.distrib import sharding as shd
from repro.optim import adamw
mesh = jax.make_mesh((2,2), ("data","model"))
set_activation_sharding(True, dp=("data",), dp_size=2, model_size=2)
cfg = cb.get_reduced("llama3_8b")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
abstract = model.abstract_params()
axes = model.param_axes()
param_sh = shd.param_shardings(axes, abstract, mesh)
params = jax.device_put(params, param_sh)
opt = adamw.init(params)
step = make_train_step(model, act_dtype=jnp.float32, remat=False, total_steps=5)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 17), 0, cfg.vocab_size)
batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
with mesh:
    fn = jax.jit(step, in_shardings=(param_sh, adamw.AdamWState(shd.replicated(mesh), param_sh, param_sh), None))
    p2, o2, m = fn(params, opt, batch)
assert np.isfinite(float(m["loss"]))
# gradient math must match single-device exactly
set_activation_sharding(False)
p_ref, _, m_ref = jax.jit(step)(jax.device_get(params), adamw.init(jax.device_get(params)), batch)
assert abs(float(m["loss"]) - float(m_ref["loss"])) < 1e-4, (float(m["loss"]), float(m_ref["loss"]))
print("ok")
""")
