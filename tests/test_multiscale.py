"""Multiscale quantized-GW subsystem: anchors, compression, refinement,
the registered quantized_gw solver (accuracy vs dense, jit+vmap
composition, base-solver nesting), and the n=10k CPU regime."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import Geometry, QuadraticProblem, QuantizedGWSolver, solve
from repro.api.output import QuantizedCoupling
from repro.multiscale import (
    AnchorAssignment,
    compress_linear_cost,
    compress_problem,
    member_table,
    membership,
    select_anchors,
)

KEY = jax.random.PRNGKey(0)


def _cloud(key, n, d=2, scale=1.0):
    x = jax.random.normal(key, (n, d)) * scale
    return jnp.sqrt(jnp.sum((x[:, None] - x[None, :]) ** 2, -1))


def _problem(seed=0, n=60, loss="l2", scale_y=1.2, **kw):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    Cx = _cloud(kx, n)
    Cy = _cloud(ky, n, scale=scale_y)
    a = b = jnp.ones(n) / n
    return QuadraticProblem(Geometry(Cx, a), Geometry(Cy, b), loss=loss, **kw)


# ---------------------------------------------------------------------------
# anchors
# ---------------------------------------------------------------------------

def test_select_anchors_partition_and_weights():
    n, k = 50, 12
    D = _cloud(KEY, n)
    a = jax.random.dirichlet(jax.random.PRNGKey(1), jnp.ones(n))
    anch = select_anchors(KEY, D, a, k)
    assert isinstance(anch, AnchorAssignment)
    assert anch.indices.shape == (k,)
    assert anch.assign.shape == (n,)
    assert int(anch.assign.min()) >= 0 and int(anch.assign.max()) < k
    # aggregated anchor weights conserve the marginal mass exactly
    np.testing.assert_allclose(float(anch.weights.sum()), float(a.sum()),
                               rtol=1e-6)
    # every anchor is a member of its own cluster
    np.testing.assert_array_equal(np.asarray(anch.assign[anch.indices]),
                                  np.arange(k))


def test_select_anchors_deterministic_given_key():
    D = _cloud(KEY, 40)
    a = jnp.ones(40) / 40
    a1 = select_anchors(jax.random.PRNGKey(3), D, a, 8)
    a2 = select_anchors(jax.random.PRNGKey(3), D, a, 8)
    np.testing.assert_array_equal(np.asarray(a1.indices),
                                  np.asarray(a2.indices))
    a3 = select_anchors(jax.random.PRNGKey(4), D, a, 8)
    assert a3.indices.shape == (8,)          # different key still valid


def test_fps_anchors_are_distinct():
    D = _cloud(KEY, 40)
    anch = select_anchors(KEY, D, jnp.ones(40) / 40, 16, refine_iters=0)
    assert len(set(np.asarray(anch.indices).tolist())) == 16


def test_select_anchors_rejects_unknown_method():
    D = _cloud(KEY, 20)
    with pytest.raises(ValueError, match="anchor method"):
        select_anchors(KEY, D, jnp.ones(20) / 20, 4, method="bogus")


def test_member_table_partitions_points():
    n, k = 37, 7
    D = _cloud(KEY, n)
    anch = select_anchors(KEY, D, jnp.ones(n) / n, k)
    table, dropped = member_table(anch.assign, k, cap=n)
    # with cap = n nothing is dropped and every point appears exactly once
    assert not bool(dropped.any())
    entries = np.asarray(table[table >= 0])
    assert sorted(entries.tolist()) == list(range(n))
    # a tight cap drops the overflow members, and only those
    cap = 2
    table2, dropped2 = member_table(anch.assign, k, cap=cap)
    counts = np.bincount(np.asarray(anch.assign), minlength=k)
    assert int(dropped2.sum()) == int(np.maximum(counts - cap, 0).sum())


def test_membership_columns_are_distributions():
    n, k = 30, 6
    D = _cloud(KEY, n)
    a = jax.random.dirichlet(jax.random.PRNGKey(2), jnp.ones(n))
    anch = select_anchors(KEY, D, a, k)
    P = membership(anch, a)
    occupied = np.asarray(anch.weights) > 0
    np.testing.assert_allclose(np.asarray(P.sum(0))[occupied], 1.0, atol=1e-5)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_compress_problem_structure():
    prob = _problem(n=40)
    ax = select_anchors(jax.random.PRNGKey(1), prob.geom_x.cost,
                        prob.geom_x.weights, 10)
    ay = select_anchors(jax.random.PRNGKey(2), prob.geom_y.cost,
                        prob.geom_y.weights, 12)
    cp = compress_problem(prob, ax, ay)
    assert cp.shape == (10, 12)
    assert cp.loss == prob.loss
    np.testing.assert_allclose(float(cp.geom_x.weights.sum()), 1.0, rtol=1e-6)
    # identity compression (k = n) with anchor metric reproduces the
    # problem up to a permutation of points
    ax_full = select_anchors(jax.random.PRNGKey(1), prob.geom_x.cost,
                             prob.geom_x.weights, 40)
    cp_full = compress_problem(prob, ax_full, ay, metric="anchor")
    perm = np.asarray(ax_full.indices)
    np.testing.assert_allclose(np.asarray(cp_full.geom_x.cost),
                               np.asarray(prob.geom_x.cost)[perm][:, perm],
                               atol=1e-6)


def test_compress_linear_cost_conditional_average():
    n = 30
    prob = _problem(n=n)
    ax = select_anchors(jax.random.PRNGKey(1), prob.geom_x.cost,
                        prob.geom_x.weights, 6)
    ay = select_anchors(jax.random.PRNGKey(2), prob.geom_y.cost,
                        prob.geom_y.weights, 6)
    # a constant linear cost must stay that constant under aggregation
    M = jnp.full((n, n), 0.7)
    Mk = compress_linear_cost(M, ax, ay, prob.geom_x.weights,
                              prob.geom_y.weights)
    occ = (np.asarray(ax.weights)[:, None] > 0) & (np.asarray(ay.weights)[None, :] > 0)
    np.testing.assert_allclose(np.asarray(Mk)[occ], 0.7, rtol=1e-5)


def test_compress_floors_empty_cluster_weights():
    """An empty cluster aggregates to weight 0; XLA CPU subnormal flush
    would turn that into log(0) = -inf inside the coarse Sinkhorn and
    (via _finite clamping) hand the empty anchor full kernel mass. The
    compress boundary must floor weights at a normal float32."""
    from repro.core.sinkhorn import sinkhorn_log
    from repro.multiscale import AnchorAssignment
    from repro.multiscale.compress import compress_geometry

    anch = AnchorAssignment(indices=jnp.array([0, 1, 2], jnp.int32),
                            assign=jnp.array([0, 0, 1, 1], jnp.int32),
                            weights=jnp.array([0.5, 0.5, 0.0]))
    geom = Geometry(_cloud(KEY, 4), jnp.ones(4) / 4)
    ck = compress_geometry(geom, anch)
    assert float(ck.weights.min()) >= 1e-30
    T = sinkhorn_log(ck.weights, ck.weights, -ck.cost / 1e-2, 200, tol=1e-9)
    assert float(T[2].sum()) < 1e-6        # empty anchor stays massless


def test_quantized_on_adjacency_costs():
    """0/1 graph adjacency costs trigger duplicate medoids / empty
    clusters; the pipeline must stay finite end-to-end."""
    n = 60
    key_g = jax.random.PRNGKey(11)
    A = (jax.random.uniform(key_g, (n, n)) < 0.1).astype(jnp.float32)
    A = jnp.triu(A, 1)
    A = A + A.T
    deg = A.sum(1) + 1e-6
    a = deg / deg.sum()
    prob = QuadraticProblem(Geometry(A, a), Geometry(A, a))
    out = solve(prob, QuantizedGWSolver(k_x=12, k_y=12),
                key=jax.random.PRNGKey(0))
    assert np.isfinite(float(out.value))


def test_mean_metric_compression_is_conditional_average():
    n, k = 24, 24
    prob = _problem(n=n)
    ax = select_anchors(jax.random.PRNGKey(1), prob.geom_x.cost,
                        prob.geom_x.weights, k)
    # k = n: the mean metric equals the permuted cost matrix exactly
    cp_mean = compress_problem(prob, ax, ax)
    perm = np.asarray(ax.indices)
    np.testing.assert_allclose(np.asarray(cp_mean.geom_x.cost),
                               np.asarray(prob.geom_x.cost)[perm][:, perm],
                               atol=1e-5)


# ---------------------------------------------------------------------------
# the quantized_gw solver
# ---------------------------------------------------------------------------

def test_quantized_registered():
    assert "quantized_gw" in repro.available_solvers()
    assert repro.get_solver("quantized_gw") is QuantizedGWSolver


def test_quantized_requires_key():
    with pytest.raises(ValueError, match="PRNGKey"):
        solve(_problem(), QuantizedGWSolver(k_x=8, k_y=8))


def test_quantized_matches_dense_within_5pct():
    """Acceptance: ≤5% relative error vs dense_gw on n≤200 point clouds."""
    n, k = 150, 75
    dense = repro.DenseGWSolver(epsilon=1e-2, outer_iters=60,
                                inner_iters=2000, tol=1e-6, inner_tol=1e-8)
    for seed in (0, 1):
        prob = _problem(seed=seed, n=n)
        ref = solve(prob, dense)
        out = solve(prob, QuantizedGWSolver(k_x=k, k_y=k),
                    key=jax.random.PRNGKey(7))
        rel = abs(float(out.value) - float(ref.value)) / abs(float(ref.value))
        assert rel <= 0.05, (
            f"seed {seed}: quantized {float(out.value):.5f} vs dense "
            f"{float(ref.value):.5f} (rel {rel:.4f})")


def test_quantized_coupling_marginals_near_exact():
    n = 100
    prob = _problem(n=n)
    out = solve(prob, QuantizedGWSolver(k_x=50, k_y=50),
                key=jax.random.PRNGKey(7))
    assert isinstance(out.coupling, QuantizedCoupling)
    mu, nu = out.coupling.marginals(n, n)
    err = float(jnp.abs(mu - prob.geom_x.weights).sum()
                + jnp.abs(nu - prob.geom_y.weights).sum())
    assert err < 0.05      # typically ~2e-2 here; exact marginals need
    # a longer polish (the refinement stage itself is marginal-exact up
    # to the coarse solve's own violation and the local Sinkhorn budget)
    dense = out.coupling.todense(n, n)
    np.testing.assert_allclose(float(dense.sum()), 1.0, atol=0.01)
    rows, cols, vals = out.coupling.tocoo()
    assert rows.shape == cols.shape == vals.shape
    np.testing.assert_allclose(float(vals.sum()), float(dense.sum()),
                               rtol=1e-6)


def test_quantized_nests_any_base_solver():
    """base accepts other registered solver configs (and name strings)."""
    prob = _problem(n=60)
    key = jax.random.PRNGKey(5)
    spar = solve(prob, QuantizedGWSolver(
        k_x=24, k_y=24, base=repro.SparGWSolver(tol=1e-6, inner_tol=1e-8)),
        key=key)
    assert np.isfinite(float(spar.value))
    named = QuantizedGWSolver(k_x=24, k_y=24, base="dense_gw")
    assert isinstance(named.base, repro.DenseGWSolver)
    assert np.isfinite(float(solve(prob, named, key=key).value))


def test_quantized_fused_and_unbalanced_and_l1():
    prob_f = _problem(n=60, M=jax.random.uniform(jax.random.PRNGKey(9),
                                                 (60, 60)),
                      fused_penalty=0.6)
    key = jax.random.PRNGKey(5)
    solver = QuantizedGWSolver(k_x=20, k_y=20)
    assert np.isfinite(float(solve(prob_f, solver, key=key).value))
    # unbalanced: coarse-value path, refinement still emits a coupling
    out_u = solve(_problem(n=60, lam=1.0), solver, key=key)
    assert np.isfinite(float(out_u.value))
    assert isinstance(out_u.coupling, QuantizedCoupling)
    # indecomposable loss exercises the profile-cost fallback
    assert np.isfinite(float(solve(_problem(n=60, loss="l1"), solver,
                                   key=key).value))


def test_coarse_value_debias_tightens_vs_dense():
    """ROADMAP item: the raw coarse value at k=√n-scale carries the
    quantization bias of the compressed objective (it drops the
    within-cluster cost variance, a large *under*-estimate when the
    spaces are genuinely mismatched). The debiased estimator swaps the
    compressed f-terms for the exact fine ones and must land closer to
    the converged dense value."""
    n, scale_y = 200, 1.5
    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (n, 3))
    y = jax.random.normal(ky, (n, 3)) * scale_y
    a = b = jnp.ones(n) / n
    prob = QuadraticProblem(Geometry.from_points(x, a),
                            Geometry.from_points(y, b))
    dense = repro.DenseGWSolver(epsilon=1e-2, outer_iters=60,
                                inner_iters=2000, tol=1e-6, inner_tol=1e-8)
    ref = float(solve(prob, dense).value)
    for k in (12, 20):
        kw = dict(k_x=k, k_y=k, value_mode="coarse", polish_iters=0)
        raw = float(solve(prob, QuantizedGWSolver(debias=False, **kw),
                          key=jax.random.PRNGKey(7)).value)
        deb = float(solve(prob, QuantizedGWSolver(debias=True, **kw),
                          key=jax.random.PRNGKey(7)).value)
        err_raw = abs(raw - ref) / abs(ref)
        err_deb = abs(deb - ref) / abs(ref)
        assert err_deb < err_raw, (
            f"k={k}: debiased err {err_deb:.3f} !< raw err {err_raw:.3f} "
            f"(raw {raw:.3f}, debiased {deb:.3f}, dense {ref:.3f})")


def test_quantized_value_mode_validation():
    with pytest.raises(ValueError, match="value_mode"):
        QuantizedGWSolver(value_mode="bogus")
    with pytest.raises(NotImplementedError, match="balanced-only"):
        solve(_problem(n=60, lam=1.0),
              QuantizedGWSolver(k_x=8, k_y=8, value_mode="refined",
                                polish_iters=0),
              key=KEY)
    with pytest.raises(NotImplementedError, match="polish"):
        solve(_problem(n=60, lam=1.0),
              QuantizedGWSolver(k_x=8, k_y=8, polish_iters=3), key=KEY)


def test_quantized_epsilon_is_dynamic_leaf():
    """ε sweeps (outer refine ε and nested base ε) must not retrace."""
    s1 = QuantizedGWSolver(k_x=8, k_y=8, epsilon=1e-2)
    s2 = QuantizedGWSolver(k_x=8, k_y=8, epsilon=5e-2)
    l1_, t1 = jax.tree_util.tree_flatten(s1)
    l2_, t2 = jax.tree_util.tree_flatten(s2)
    assert t1 == t2
    assert 1e-2 in l1_ and 5e-2 in l2_
    # nested base epsilon is a leaf too
    s3 = QuantizedGWSolver(
        k_x=8, k_y=8, base=repro.DenseGWSolver(epsilon=3e-2))
    l3, t3 = jax.tree_util.tree_flatten(s3)
    assert 3e-2 in l3
    # a static knob change IS a structure change
    _, t4 = jax.tree_util.tree_flatten(QuantizedGWSolver(k_x=16, k_y=8))
    assert t4 != t1


def test_quantized_jit_vmap_stack_matches_per_problem():
    """Acceptance: composes with jax.jit + jax.vmap over a problem stack.

    Fixed iteration budgets (tol=0) keep the batched and per-problem
    runs on identical control flow; top-k tie reordering between the two
    lowerings permutes block order, so couplings are compared densified.
    """
    B, n = 3, 60
    base = repro.DenseGWSolver(outer_iters=10, inner_iters=200, tol=0.0,
                               inner_tol=0.0)
    solver = QuantizedGWSolver(k_x=24, k_y=24, base=base, refine_iters=100,
                               refine_tol=0.0, polish_iters=3,
                               polish_inner_iters=300)
    probs = [_problem(seed=s, n=n) for s in range(B)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *probs)
    keys = jax.random.split(jax.random.PRNGKey(5), B)
    out = jax.jit(jax.vmap(lambda p, k: solve(p, solver, key=k)))(stacked,
                                                                  keys)
    assert out.value.shape == (B,)
    assert out.coupling.blocks.shape[0] == B
    for i in range(B):
        ref = solve(probs[i], solver, key=keys[i])
        np.testing.assert_allclose(float(out.value[i]), float(ref.value),
                                   rtol=1e-4, atol=1e-6)
        Tb = QuantizedCoupling(*[x[i] for x in out.coupling]).todense(n, n)
        Tr = ref.coupling.todense(n, n)
        np.testing.assert_allclose(np.asarray(Tb), np.asarray(Tr),
                                   atol=2e-4)


def test_quantized_10k_cpu_completes():
    """Acceptance: n=10k with k=√n-scale anchors completes on CPU (where
    dense_gw's O(n³)-per-iteration loop is infeasible)."""
    n = 10_000
    rng = np.random.default_rng(0)

    def dists(seed):
        r = np.random.default_rng(seed)
        x = r.standard_normal((n, 3)).astype(np.float32)
        sq = (x ** 2).sum(1)
        return jnp.asarray(np.sqrt(np.maximum(
            sq[:, None] + sq[None, :] - 2 * x @ x.T, 0), dtype=np.float32))

    del rng
    a = b = jnp.ones((n,), jnp.float32) / n
    prob = QuadraticProblem(Geometry(dists(0), a), Geometry(dists(1), b))
    t0 = time.time()
    out = solve(prob, QuantizedGWSolver(), key=jax.random.PRNGKey(0))
    value = float(out.value)          # blocks until the solve finishes
    elapsed = time.time() - t0
    assert np.isfinite(value)
    assert out.coupling.blocks.shape == (400, 300, 300)
    mu, _ = out.coupling.marginals(n, n)
    assert np.isfinite(float(mu.sum()))
    assert elapsed < 600, f"n=10k solve took {elapsed:.0f}s"
