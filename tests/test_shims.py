"""Every legacy ``repro.core.*`` entry point is a deprecation shim over
``repro.solve`` — each one must actually raise DeprecationWarning."""
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    egw,
    fgw_dense,
    grid_spar_gw,
    gw_dense,
    pga_gw,
    spar_fgw,
    spar_gw,
    spar_ugw,
    ugw_dense,
)

N = 12
KEY = jax.random.PRNGKey(0)


def _data():
    kx, ky = jax.random.split(KEY)
    x = jax.random.normal(kx, (N, 2))
    y = jax.random.normal(ky, (N, 2))
    Cx = jnp.sqrt(jnp.sum((x[:, None] - x[None, :]) ** 2, -1))
    Cy = jnp.sqrt(jnp.sum((y[:, None] - y[None, :]) ** 2, -1))
    a = b = jnp.ones(N) / N
    return a, b, Cx, Cy


FAST = dict(outer_iters=1, inner_iters=2)
_M = jnp.zeros((N, N))

SHIMS = {
    "spar_gw": lambda a, b, Cx, Cy: spar_gw(KEY, a, b, Cx, Cy, s=2 * N,
                                            **FAST),
    "spar_fgw": lambda a, b, Cx, Cy: spar_fgw(KEY, a, b, Cx, Cy, _M,
                                              s=2 * N, **FAST),
    "spar_ugw": lambda a, b, Cx, Cy: spar_ugw(KEY, a, b, Cx, Cy, s=2 * N,
                                              lam=1.0, **FAST),
    "gw_dense": lambda a, b, Cx, Cy: gw_dense(a, b, Cx, Cy, **FAST),
    "egw": lambda a, b, Cx, Cy: egw(a, b, Cx, Cy, **FAST),
    "pga_gw": lambda a, b, Cx, Cy: pga_gw(a, b, Cx, Cy, **FAST),
    "fgw_dense": lambda a, b, Cx, Cy: fgw_dense(a, b, Cx, Cy, _M, **FAST),
    "ugw_dense": lambda a, b, Cx, Cy: ugw_dense(a, b, Cx, Cy, lam=1.0,
                                                **FAST),
    "grid_spar_gw": lambda a, b, Cx, Cy: grid_spar_gw(KEY, a, b, Cx, Cy,
                                                      s_r=4, s_c=4, **FAST),
}


@pytest.mark.parametrize("name", sorted(SHIMS))
def test_shim_raises_deprecation_warning(name):
    a, b, Cx, Cy = _data()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        SHIMS[name](a, b, Cx, Cy)
    deprecations = [w for w in caught
                    if issubclass(w.category, DeprecationWarning)
                    and "repro.core." in str(w.message)]
    assert deprecations, f"{name} did not warn DeprecationWarning"
    # the message must point at the replacement entry point
    assert any("repro.solve" in str(w.message) for w in deprecations)
