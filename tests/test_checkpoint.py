"""Checkpoint manager: roundtrip, atomicity, keep-k GC, async writes."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 5)),
            "nested": {"b": jnp.arange(7), "c": (jnp.ones(3), jnp.zeros(2))}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    mgr.save(3, t, extra={"pipeline": {"step": 3, "seed": 9}})
    restored, extra = mgr.restore(3, t)
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.array(x), np.array(y))
    assert extra["pipeline"]["step"] == 3


def test_partial_write_invisible(tmp_path):
    """A .tmp dir (crashed writer) must never be picked up."""
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, _tree())
    os.makedirs(tmp_path / "step_0000000002.tmp")
    assert mgr.latest_step() == 1
    # a step dir without manifest (mid-rename crash impossible with
    # os.replace, but simulate corruption) is also skipped
    os.makedirs(tmp_path / "step_0000000005")
    assert mgr.latest_step() == 1


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(7, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 7


def test_restore_different_structure_order(tmp_path):
    """Restore is keyed by path, not flatten order."""
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    mgr.save(1, t)
    target = {"nested": {"c": (jnp.zeros(3), jnp.ones(2)),
                         "b": jnp.zeros(7, jnp.int32)},
              "a": jnp.zeros((4, 5))}
    restored, _ = mgr.restore(1, target)
    np.testing.assert_array_equal(np.array(restored["nested"]["b"]),
                                  np.arange(7))
