"""core/sampling.py edge cases: support sizes that don't divide chunk/block
sizes, near-degenerate marginal weights, and duplicate sampled pairs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import sampling

KEY = jax.random.PRNGKey(0)


def _cloud(key, n, scale=1.0):
    x = jax.random.normal(key, (n, 2)) * scale
    return jnp.sqrt(jnp.sum((x[:, None] - x[None, :]) ** 2, -1))


def _problem(n, a=None, b=None):
    kx, ky = jax.random.split(KEY)
    if a is None:
        a = b = jnp.ones(n) / n
    return repro.QuadraticProblem(
        repro.Geometry(_cloud(kx, n), a),
        repro.Geometry(_cloud(ky, n, 1.2), b))


# ---------------------------------------------------------------------------
# s not a block/chunk multiple
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [1, 7, 37])
def test_sample_pairs_odd_sizes(s):
    n = 20
    a = b = jnp.ones(n) / n
    probs = sampling.balanced_probs(a, b)
    rows, cols = sampling.sample_pairs(KEY, probs, s)
    assert rows.shape == cols.shape == (s,)
    assert int(rows.min()) >= 0 and int(rows.max()) < n
    p = probs.pair_prob(rows, cols)
    assert bool(jnp.all(p > 0))


def test_spar_solve_with_non_chunk_multiple_support():
    """End-to-end: s=37 with cost_chunk=16 (37 % 16 != 0) must work and
    match the same solve with a divisible chunk."""
    n = 16
    prob = _problem(n)
    out_a = repro.solve(prob, repro.SparGWSolver(
        s=37, cost_chunk=16, outer_iters=3, inner_iters=10), key=KEY)
    out_b = repro.solve(prob, repro.SparGWSolver(
        s=37, cost_chunk=37, outer_iters=3, inner_iters=10), key=KEY)
    assert out_a.coupling.vals.shape == (37,)
    np.testing.assert_allclose(np.asarray(out_a.coupling.vals),
                               np.asarray(out_b.coupling.vals),
                               rtol=1e-5, atol=1e-8)


# ---------------------------------------------------------------------------
# near-degenerate marginal weights
# ---------------------------------------------------------------------------

def test_balanced_probs_near_degenerate_weights():
    """One point carries ~all mass; the rest are ~1e-12. Probabilities must
    stay finite, normalized, and (with shrink) bounded away from zero."""
    n = 30
    a = jnp.full((n,), 1e-12).at[0].set(1.0)
    a = a / a.sum()
    b = jnp.ones(n) / n
    probs = sampling.balanced_probs(a, b)
    assert bool(jnp.all(jnp.isfinite(probs.pa)))
    np.testing.assert_allclose(float(probs.pa.sum()), 1.0, rtol=1e-5)
    rows, cols = sampling.sample_pairs(KEY, probs, 64)
    assert bool(jnp.all((rows >= 0) & (rows < n)))
    # shrink enforces the regularity floor p_i >= shrink/n (H.4)
    shrunk = sampling.balanced_probs(a, b, shrink=0.1)
    assert float(shrunk.pa.min()) >= 0.1 / n - 1e-9


def test_degenerate_weights_solve_is_finite():
    n = 24
    a = jnp.full((n,), 1e-10).at[3].set(1.0)
    a = a / a.sum()
    prob = _problem(n, a=a, b=jnp.ones(n) / n)
    out = repro.solve(prob, repro.SparGWSolver(
        s=8 * n, shrink=0.1, outer_iters=5, inner_iters=50), key=KEY)
    assert np.isfinite(float(out.value))
    assert bool(jnp.all(jnp.isfinite(out.coupling.vals)))


def test_unbalanced_probs_extreme_logk():
    """unbalanced_probs takes log K; a huge dynamic range must not NaN."""
    n = 10
    a = b = jnp.ones(n) / n
    logK = jnp.linspace(-500.0, 0.0, n * n).reshape(n, n)
    P = sampling.unbalanced_probs(a, b, logK, lam=1.0, eps=1e-2)
    assert bool(jnp.all(jnp.isfinite(P)))
    np.testing.assert_allclose(float(P.sum()), 1.0, rtol=1e-5)
    rows, cols = sampling.sample_pairs_2d(KEY, P, 16)
    assert rows.shape == (16,)


# ---------------------------------------------------------------------------
# duplicate sampled pairs
# ---------------------------------------------------------------------------

def test_duplicate_pairs_semantics():
    """n tiny, s large → duplicates guaranteed. Duplicates are parallel
    importance-sampling draws: todense must merge them by summation and
    conserve the coupling mass."""
    n, s = 4, 64
    prob = _problem(n)
    out = repro.solve(prob, repro.SparGWSolver(
        s=s, outer_iters=5, inner_iters=50), key=KEY)
    rows = np.asarray(out.coupling.rows)
    cols = np.asarray(out.coupling.cols)
    assert len(set(zip(rows.tolist(), cols.tolist()))) < s   # duplicates exist
    dense = out.coupling.todense(n, n)
    np.testing.assert_allclose(float(dense.sum()),
                               float(out.coupling.vals.sum()), rtol=1e-6)
    # dense coupling ~doubly stochastic up to solver tolerance
    assert float(jnp.abs(dense.sum(1) - prob.geom_x.weights).sum()) < 0.2


def test_sample_pairs_2d_duplicates_match_flat_probs():
    n = 3
    P = jnp.arange(1.0, n * n + 1).reshape(n, n)
    P = P / P.sum()
    rows, cols = sampling.sample_pairs_2d(KEY, P, 1000)
    freq = np.zeros((n, n))
    np.add.at(freq, (np.asarray(rows), np.asarray(cols)), 1.0 / 1000)
    np.testing.assert_allclose(freq, np.asarray(P), atol=0.05)
