"""Unified Problem/Solver/Output API: pytree round-trips, validation,
jit+vmap batched solves, shim equivalence, registry, early stopping."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import (
    DenseGWSolver,
    Geometry,
    GridGWSolver,
    GWOutput,
    QuadraticProblem,
    SparGWSolver,
    available_solvers,
    get_solver,
    register_solver,
    solve,
)
from repro.core import grid_spar_gw, gw_dense, spar_fgw, spar_gw, spar_ugw

KEY = jax.random.PRNGKey(0)
N = 20
FAST = dict(outer_iters=5, inner_iters=20)


def _cloud(key, n, d=2, scale=1.0, shift=0.0):
    x = jax.random.normal(key, (n, d)) * scale + shift
    return jnp.sqrt(jnp.sum((x[:, None] - x[None, :]) ** 2, -1))


def _problem(seed=0, n=N, loss="l2", **kw):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    Cx = _cloud(kx, n)
    Cy = _cloud(ky, n, scale=1.2)
    a = b = jnp.ones(n) / n
    return QuadraticProblem(Geometry(Cx, a), Geometry(Cy, b), loss=loss, **kw)


# ---------------------------------------------------------------------------
# pytree structure
# ---------------------------------------------------------------------------

def test_problem_pytree_roundtrip():
    prob = _problem()
    leaves, treedef = jax.tree_util.tree_flatten(prob)
    assert all(isinstance(l, jax.Array) for l in leaves)
    prob2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(prob2, QuadraticProblem)
    assert prob2.loss == prob.loss and prob2.shape == prob.shape
    np.testing.assert_array_equal(np.asarray(prob2.geom_x.cost),
                                  np.asarray(prob.geom_x.cost))


def test_output_pytree_roundtrip():
    out = solve(_problem(), SparGWSolver(s=4 * N, **FAST), key=KEY)
    leaves, treedef = jax.tree_util.tree_flatten(out)
    out2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(out2, GWOutput)
    np.testing.assert_array_equal(np.asarray(out2.coupling.vals),
                                  np.asarray(out.coupling.vals))
    assert float(out2.value) == float(out.value)


def test_solver_pytree_epsilon_is_leaf():
    """ε sweeps must not retrace: ε is the only dynamic leaf of a solver."""
    s1 = SparGWSolver(s=64, epsilon=1e-2)
    s2 = SparGWSolver(s=64, epsilon=5e-2)
    l1, t1 = jax.tree_util.tree_flatten(s1)
    l2, t2 = jax.tree_util.tree_flatten(s2)
    assert t1 == t2                      # same structure -> same jit cache
    assert l1 == [1e-2] and l2 == [5e-2]
    # but a static knob change IS a structure change
    _, t3 = jax.tree_util.tree_flatten(SparGWSolver(s=128, epsilon=1e-2))
    assert t3 != t1


def test_variant_dispatch_is_structural():
    """lam / M presence selects the variant via the pytree structure."""
    assert not _problem().is_unbalanced and not _problem().is_fused
    p_u = _problem(lam=1.0)
    assert p_u.is_unbalanced
    M = jnp.zeros((N, N))
    p_f = _problem(M=M, fused_penalty=0.5)
    assert p_f.is_fused
    _, t_plain = jax.tree_util.tree_flatten(_problem())
    _, t_u = jax.tree_util.tree_flatten(p_u)
    assert t_plain != t_u


# ---------------------------------------------------------------------------
# validation at the Problem boundary
# ---------------------------------------------------------------------------

def test_validation_rejects_nonsquare_cost():
    a = jnp.ones(N) / N
    with pytest.raises(ValueError, match="square"):
        Geometry(jnp.zeros((N, N - 1)), a)


def test_validation_rejects_marginal_length_mismatch():
    with pytest.raises(ValueError, match="weights must have shape"):
        Geometry(jnp.zeros((N, N)), jnp.ones(N + 3) / (N + 3))


def test_validation_rejects_unnormalized_weights():
    C = _cloud(KEY, N)
    a = jnp.ones(N) / N
    with pytest.raises(ValueError, match="sum to 1"):
        QuadraticProblem(Geometry(C, a * 2.0), Geometry(C, a))
    # ... but unbalanced problems allow arbitrary masses
    QuadraticProblem(Geometry(C, a * 2.0), Geometry(C, a), lam=1.0)


def test_validation_rejects_bad_fused_config():
    C = _cloud(KEY, N)
    a = jnp.ones(N) / N
    M = jnp.zeros((N, N))
    with pytest.raises(ValueError, match="fused_penalty"):
        QuadraticProblem(Geometry(C, a), Geometry(C, a), M=M)
    with pytest.raises(ValueError, match="linear term"):
        QuadraticProblem(Geometry(C, a), Geometry(C, a), fused_penalty=0.5)
    with pytest.raises(ValueError, match="must have shape"):
        QuadraticProblem(Geometry(C, a), Geometry(C, a),
                         M=jnp.zeros((N, N + 1)), fused_penalty=0.5)


def test_validation_optout_and_tracer_safety():
    C = _cloud(KEY, N)
    a = jnp.ones(N) / N
    # opt-out flag: no value checks
    QuadraticProblem(Geometry(C, a * 2.0, validate=False),
                     Geometry(C, a), validate=False)

    # value checks auto-skip under tracing; construction inside jit works
    @jax.jit
    def build_and_solve(C, a):
        prob = QuadraticProblem(Geometry(C, a), Geometry(C, a), loss="l2")
        return solve(prob, DenseGWSolver(outer_iters=2, inner_iters=5)).value

    assert np.isfinite(float(build_and_solve(C, a)))


# ---------------------------------------------------------------------------
# shim equivalence: old entry points == repro.solve, bitwise
# ---------------------------------------------------------------------------

def test_shim_spar_gw_bitwise():
    prob = _problem()
    solver = SparGWSolver(s=4 * N, **FAST)
    out = solve(prob, solver, key=KEY)
    v, (r, c, t) = spar_gw(KEY, prob.geom_x.weights, prob.geom_y.weights,
                           prob.geom_x.cost, prob.geom_y.cost, s=4 * N, **FAST)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(out.value))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(out.coupling.rows))
    np.testing.assert_array_equal(np.asarray(t), np.asarray(out.coupling.vals))


def test_shim_spar_fgw_bitwise():
    M = jax.random.uniform(jax.random.PRNGKey(5), (N, N))
    prob = _problem(M=M, fused_penalty=0.7)
    out = solve(prob, SparGWSolver(s=4 * N, **FAST), key=KEY)
    v, (_, _, t) = spar_fgw(KEY, prob.geom_x.weights, prob.geom_y.weights,
                            prob.geom_x.cost, prob.geom_y.cost, M, s=4 * N,
                            alpha=0.7, **FAST)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(out.value))
    np.testing.assert_array_equal(np.asarray(t), np.asarray(out.coupling.vals))


def test_shim_spar_ugw_bitwise():
    prob = _problem(lam=1.0)
    out = solve(prob, SparGWSolver(s=4 * N, **FAST), key=KEY)
    v, (_, _, t) = spar_ugw(KEY, prob.geom_x.weights, prob.geom_y.weights,
                            prob.geom_x.cost, prob.geom_y.cost, s=4 * N,
                            lam=1.0, **FAST)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(out.value))
    np.testing.assert_array_equal(np.asarray(t), np.asarray(out.coupling.vals))


def test_shim_gw_dense_bitwise():
    prob = _problem()
    out = solve(prob, DenseGWSolver(**FAST))
    v, T = gw_dense(prob.geom_x.weights, prob.geom_y.weights,
                    prob.geom_x.cost, prob.geom_y.cost, **FAST)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(out.value))
    np.testing.assert_array_equal(np.asarray(T), np.asarray(out.coupling))


def test_shim_grid_spar_gw_bitwise():
    prob = _problem()
    out = solve(prob, GridGWSolver(s_r=16, s_c=16, **FAST), key=KEY)
    v, (R, C, T) = grid_spar_gw(KEY, prob.geom_x.weights, prob.geom_y.weights,
                                prob.geom_x.cost, prob.geom_y.cost,
                                s_r=16, s_c=16, **FAST)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(out.value))
    np.testing.assert_array_equal(np.asarray(R), np.asarray(out.coupling.rows))
    np.testing.assert_array_equal(np.asarray(T), np.asarray(out.coupling.block))


def test_shims_warn_deprecation():
    prob = _problem()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        spar_gw(KEY, prob.geom_x.weights, prob.geom_y.weights,
                prob.geom_x.cost, prob.geom_y.cost, s=2 * N, outer_iters=1,
                inner_iters=2)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)


# ---------------------------------------------------------------------------
# jit + vmap batching (acceptance criterion)
# ---------------------------------------------------------------------------

def _stacked_problems(n_problems, **prob_kw):
    probs = [_problem(seed=s, **prob_kw) for s in range(n_problems)]
    return probs, jax.tree.map(lambda *xs: jnp.stack(xs), *probs)


@pytest.mark.parametrize("variant", ["gw", "fgw", "ugw"])
def test_solve_vmap_batched_matches_legacy(variant):
    """repro.solve under a single jit over a vmap-batched stack of 4
    problems; unbatched slices must match the legacy entry points."""
    B = 4
    kw = {}
    if variant == "fgw":
        kw = dict(M=jax.random.uniform(jax.random.PRNGKey(9), (N, N)),
                  fused_penalty=0.6)
    elif variant == "ugw":
        kw = dict(lam=1.0)
    probs, stacked = _stacked_problems(B, **kw)
    keys = jax.random.split(jax.random.PRNGKey(7), B)
    solver = SparGWSolver(s=4 * N, **FAST)

    batched = jax.jit(jax.vmap(lambda p, k: solve(p, solver, key=k)))
    out = batched(stacked, keys)
    assert out.value.shape == (B,)
    assert out.coupling.vals.shape == (B, 4 * N)
    assert out.errors.shape == (B, FAST["outer_iters"])

    legacy = {"gw": lambda p, k: spar_gw(
                  k, p.geom_x.weights, p.geom_y.weights, p.geom_x.cost,
                  p.geom_y.cost, s=4 * N, **FAST),
              "fgw": lambda p, k: spar_fgw(
                  k, p.geom_x.weights, p.geom_y.weights, p.geom_x.cost,
                  p.geom_y.cost, kw["M"], s=4 * N, alpha=0.6, **FAST),
              "ugw": lambda p, k: spar_ugw(
                  k, p.geom_x.weights, p.geom_y.weights, p.geom_x.cost,
                  p.geom_y.cost, s=4 * N, lam=1.0, **FAST)}[variant]
    for i in range(B):
        v, (_, _, t) = legacy(probs[i], keys[i])
        np.testing.assert_allclose(float(out.value[i]), float(v),
                                   rtol=2e-5, atol=1e-6)
        # batched lowering reorders float ops; near-zero coupling entries
        # (log-domain exp underflow region) need an absolute tolerance
        np.testing.assert_allclose(np.asarray(out.coupling.vals[i]),
                                   np.asarray(t), rtol=1e-4, atol=1e-6)


def test_solve_vmap_dense_solver():
    B = 4
    probs, stacked = _stacked_problems(B)
    out = jax.jit(jax.vmap(lambda p: solve(p, DenseGWSolver(**FAST))))(stacked)
    assert out.coupling.shape == (B, N, N)
    for i in range(B):
        ref = solve(probs[i], DenseGWSolver(**FAST))
        np.testing.assert_allclose(float(out.value[i]), float(ref.value),
                                   rtol=2e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# convergence machinery
# ---------------------------------------------------------------------------

def test_early_stopping_reports_convergence():
    """Self-distance at moderate ε: the outer loop must stop well before
    the bound, flag convergence, and NaN-pad the error buffer."""
    C = _cloud(KEY, N)
    a = jnp.ones(N) / N
    prob = QuadraticProblem(Geometry(C, a), Geometry(C, a), loss="l2")
    out = solve(prob, DenseGWSolver(epsilon=1e-3, outer_iters=50,
                                    inner_iters=500, tol=1e-6, inner_tol=1e-7))
    n_it = int(out.n_iters)
    assert bool(out.converged) and n_it < 50
    errs = np.asarray(out.errors)
    assert np.all(np.isfinite(errs[:n_it]))
    assert np.all(np.isnan(errs[n_it:]))
    # converged marginal projection -> tiny violation at the end
    assert errs[n_it - 1] < 1e-4


def test_tol_zero_runs_full_budget():
    out = solve(_problem(), SparGWSolver(s=4 * N, **FAST), key=KEY)
    assert int(out.n_iters) == FAST["outer_iters"]
    assert not bool(out.converged)
    assert np.all(np.isfinite(np.asarray(out.errors)))


def test_inner_tol_matches_full_budget_result():
    """Early-stopped inner Sinkhorn must land where the full budget lands."""
    prob = _problem()
    full = solve(prob, DenseGWSolver(outer_iters=5, inner_iters=400))
    tolled = solve(prob, DenseGWSolver(outer_iters=5, inner_iters=400,
                                       inner_tol=1e-8))
    np.testing.assert_allclose(np.asarray(tolled.coupling),
                               np.asarray(full.coupling), atol=1e-5)


# ---------------------------------------------------------------------------
# registry + front door conveniences
# ---------------------------------------------------------------------------

def test_registry_contents():
    names = available_solvers()
    assert {"spar_gw", "dense_gw", "grid_gw"} <= set(names)
    assert get_solver("spar_gw") is SparGWSolver
    with pytest.raises(KeyError, match="unknown solver"):
        get_solver("nope")


def test_registry_extensible():
    @register_solver("test_only_solver")
    class TestOnlySolver(DenseGWSolver):
        pass
    try:
        assert get_solver("test_only_solver") is TestOnlySolver
        with pytest.raises(ValueError, match="already registered"):
            register_solver("test_only_solver")(TestOnlySolver)
        # register_solver must make the class jit-able as a pytree arg:
        # solving through the front door with the custom solver works
        out = solve(_problem(), TestOnlySolver(outer_iters=2, inner_iters=5))
        assert np.isfinite(float(out.value))
    finally:
        from repro.api import solvers as _solvers
        _solvers._REGISTRY.pop("test_only_solver")


def test_solve_by_name():
    out = solve(_problem(), "dense_gw")
    assert np.isfinite(float(out.value))


def test_select_solver_heuristic():
    """solver=None auto-selects from problem structure (ROADMAP item)."""
    from repro import DenseGWSolver as D
    from repro import QuantizedGWSolver as Q
    from repro import SparGWSolver as S
    from repro import select_solver

    def shaped(n, **kw):
        a = jnp.ones(n) / n
        g = Geometry(jnp.zeros((n, n)), a, validate=False)
        return QuadraticProblem(g, g, validate=False, **kw)

    assert isinstance(select_solver(shaped(100)), D)
    mid = select_solver(shaped(1000))
    assert isinstance(mid, S) and mid.s == 16 * 1000
    assert isinstance(select_solver(shaped(4000)), Q)
    # unbalanced problems route by size like balanced ones (spar's
    # O((16n)²) assembly is infeasible at scale; quantized handles lam)
    assert isinstance(select_solver(shaped(4000, lam=1.0)), Q)
    assert isinstance(select_solver(shaped(1000, lam=1.0)), S)
    # fused structure routes like balanced
    assert isinstance(
        select_solver(shaped(100, M=jnp.zeros((100, 100)),
                             fused_penalty=0.5)), D)


def test_select_solver_lowrank_rung():
    """The low-rank rung of the auto-routing ladder: factorizable
    point-cloud problems above the spar threshold, and any eligible
    problem above _LOWRANK_MIN, route to lowrank_gw."""
    from repro import LowRankGWSolver as L
    from repro import QuantizedGWSolver as Q
    from repro import SparGWSolver as S
    from repro import select_solver
    from repro.api.solve import _LOWRANK_MIN

    def cloud(n, loss="l2", **kw):
        a = jnp.ones(n) / n
        g = Geometry(None, a, points=jnp.zeros((n, 2)), validate=False)
        return QuadraticProblem(g, g, loss=loss, validate=False, **kw)

    def shaped(n, **kw):
        a = jnp.ones(n) / n
        g = Geometry(jnp.zeros((n, n)), a, validate=False)
        return QuadraticProblem(g, g, validate=False, **kw)

    # point clouds: lowrank as soon as spar's O(s²) stops paying off
    assert isinstance(select_solver(cloud(4000)), L)
    # ... but below the spar threshold the existing ladder is untouched
    assert isinstance(select_solver(cloud(1000)), S)
    # dense-cost problems keep quantized until _LOWRANK_MIN
    assert isinstance(select_solver(shaped(4000)), Q)
    assert isinstance(select_solver(shaped(_LOWRANK_MIN + 1)), L)
    # structure lowrank can't handle stays on quantized at any size
    assert isinstance(select_solver(cloud(4000, lam=1.0)), Q)
    assert isinstance(select_solver(shaped(_LOWRANK_MIN + 1, lam=1.0)), Q)
    assert isinstance(select_solver(cloud(4000, loss="l1")), Q)
    # kl point clouds can't use the exact factorization (it needs
    # squared-euclidean h), so they wait for the _LOWRANK_MIN threshold
    assert isinstance(select_solver(cloud(4000, loss="kl")), Q)
    assert isinstance(select_solver(cloud(_LOWRANK_MIN + 1, loss="kl")), L)
    big_M = jnp.zeros((4000, 4000))
    assert isinstance(
        select_solver(cloud(4000, M=big_M, fused_penalty=0.5)), Q)


def test_solve_with_no_solver_auto_selects():
    out = solve(_problem())          # N=20 -> dense_gw, no key needed
    ref = solve(_problem(), DenseGWSolver.default_config(N))
    np.testing.assert_array_equal(np.asarray(out.value),
                                  np.asarray(ref.value))


def test_solver_requires_key_and_support():
    prob = _problem()
    with pytest.raises(ValueError, match="PRNGKey"):
        solve(prob, SparGWSolver(s=64))
    with pytest.raises(ValueError, match="support size"):
        solve(prob, SparGWSolver(), key=KEY)


def test_grid_solver_rejects_fused_unbalanced():
    prob = _problem(lam=1.0)
    with pytest.raises(NotImplementedError):
        solve(prob, GridGWSolver(s_r=8, s_c=8), key=KEY)


def test_coupling_todense_mass():
    out = solve(_problem(), SparGWSolver(s=4 * N, **FAST), key=KEY)
    dense = out.coupling.todense(N, N)
    np.testing.assert_allclose(float(dense.sum()),
                               float(out.coupling.vals.sum()), rtol=1e-6)
    assert dense.shape == (N, N)


def test_fused_features_derive_linear_term():
    """Feature geometries (no explicit M) produce the squared-euclidean M."""
    fx = jax.random.normal(jax.random.PRNGKey(3), (N, 3))
    fy = jax.random.normal(jax.random.PRNGKey(4), (N, 3))
    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    Cx, Cy = _cloud(kx, N), _cloud(ky, N, scale=1.2)
    a = jnp.ones(N) / N
    M = jnp.sum((fx[:, None, :] - fy[None, :, :]) ** 2, -1)
    p_feat = QuadraticProblem(Geometry(Cx, a, features=fx),
                              Geometry(Cy, a, features=fy),
                              fused_penalty=0.6)
    p_M = QuadraticProblem(Geometry(Cx, a), Geometry(Cy, a),
                           M=M, fused_penalty=0.6)
    o1 = solve(p_feat, SparGWSolver(s=4 * N, **FAST), key=KEY)
    o2 = solve(p_M, SparGWSolver(s=4 * N, **FAST), key=KEY)
    np.testing.assert_allclose(float(o1.value), float(o2.value), rtol=1e-5)
