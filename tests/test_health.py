"""Numerical health & recovery layer: status detection, ε-rescue,
fault injection, fallback chain, and tiny-ε overflow paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import (
    DenseGWSolver,
    Geometry,
    GridGWSolver,
    LowRankGWSolver,
    QuadraticProblem,
    QuantizedGWSolver,
    SparGWSolver,
    solve,
)
from repro.health import (
    CONVERGED,
    DIVERGED,
    MAXITER,
    STALLED,
    FaultSpec,
    SolveDivergedError,
    SolveStatus,
    fallback_chain,
    health_loop,
)
from repro.lowrank.dykstra import lr_dykstra

KEY = jax.random.PRNGKey(0)
N = 24


def _cloud(key, n, d=2, scale=1.0):
    x = jax.random.normal(key, (n, d)) * scale
    return jnp.sqrt(jnp.sum((x[:, None] - x[None, :]) ** 2, -1))


def _problem(seed=0, n=N, loss="l2", concentrated=False, **kw):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    Cx = _cloud(kx, n)
    Cy = _cloud(ky, n, scale=1.2)
    if concentrated:
        a = jnp.full((n,), 1e-4)
        a = a.at[0].set(1.0 - (n - 1) * 1e-4)
    else:
        a = jnp.ones(n) / n
    return QuadraticProblem(Geometry(Cx, a), Geometry(Cy, a), loss=loss, **kw)


def _faulted(solver, **fault_kw):
    return dataclasses.replace(solver, max_rescues=0,
                               fault=FaultSpec(**fault_kw))


def _trees_equal(t1, t2):
    """Bitwise tree equality, treating the NaN padding of ``errors``
    (identical NaN patterns) as equal."""
    def eq(x, y):
        x, y = jnp.asarray(x), jnp.asarray(y)
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jnp.array_equal(x, y, equal_nan=True)
        return jnp.array_equal(x, y)
    return bool(jax.tree.all(jax.tree.map(eq, t1, t2)))


# every registered solver family, configured small and fast
def _fast_configs(n=N):
    return {
        "dense_gw": DenseGWSolver(tol=1e-6, inner_tol=1e-8, outer_iters=10),
        "spar_gw": SparGWSolver(s=8 * n, outer_iters=10, inner_tol=1e-8),
        "grid_gw": GridGWSolver(s_r=12, s_c=12, outer_iters=10,
                                inner_tol=1e-8),
        "lowrank_gw": LowRankGWSolver(outer_iters=30),
        "quantized_gw": QuantizedGWSolver(refine_iters=50, polish_iters=2,
                                          polish_inner_iters=50),
    }


# ---------------------------------------------------------------------------
# health_loop unit behavior
# ---------------------------------------------------------------------------

def test_healthy_loop_reports_converged():
    step = lambda T: 0.5 * T + 0.5          # noqa: E731 — contraction to 1
    err = lambda T: jnp.sum(jnp.abs(T - 1))  # noqa: E731
    T, errs, n_iters, conv, status, trace = health_loop(
        step, err, jnp.zeros(4), 100, 1e-6)
    assert trace is None                     # tracing is opt-in
    assert bool(conv)
    assert status.describe() == "CONVERGED"
    assert int(status.fail_iter) == -1
    assert int(status.n_rescues) == 0


def test_maxiter_status():
    step = lambda T: T + 1.0                 # noqa: E731 — never settles
    err = lambda T: jnp.float32(0.0)         # noqa: E731
    res = health_loop(step, err, jnp.zeros(2), 5, 1e-9)
    conv, status = res.converged, res.status
    assert not bool(conv)
    assert status.describe() == "MAXITER"


def test_stall_classification():
    """Tolerance met but the diagnostic stays large -> STALLED, not
    CONVERGED (the dense-PGA mixing-fixed-point failure mode)."""
    step = lambda T: T                       # noqa: E731 — instant fixed point
    err = lambda T: jnp.float32(0.9)         # noqa: E731 — huge violation
    res = health_loop(step, err, jnp.ones(3), 10, 1e-6)
    conv, status = res.converged, res.status
    assert bool(conv)                        # converged flag: tol was met...
    assert status.describe() == "STALLED"    # ...but the lattice knows better


def test_nan_detected_at_correct_iteration():
    def step(T):
        return jnp.where(T[0] >= 3, jnp.nan, T + 1)
    err = lambda T: jnp.float32(0.0)         # noqa: E731
    T, errs, n_iters, conv, status, _ = health_loop(
        step, err, jnp.zeros(2), 20, 0.0)
    assert status.describe() == "DIVERGED"
    assert int(status.fail_iter) == 3        # step from T[0]=3 poisons
    np.testing.assert_array_equal(np.asarray(T), 3.0)   # last healthy kept
    assert np.all(np.isfinite(np.asarray(T)))


def test_mass_explosion_is_divergence():
    """A finite but absurdly scaled iterate (overflow in progress that
    log-domain inner solves keep renormalizing around) is fatal too."""
    def step(T):
        return jnp.where(T[0] >= 2, 1e25, T + 1)
    err = lambda T: jnp.float32(0.0)         # noqa: E731
    status = health_loop(step, err, jnp.zeros(2), 20, 0.0).status
    assert status.describe() == "DIVERGED"
    assert int(status.fail_iter) == 2


def test_mass_collapse_is_divergence():
    """An all-zero iterate (underflowed kernel) is fatal even though it is
    finite — the silent tiny-ε failure mode."""
    def step(T):
        return jnp.where(T[0] >= 2, 0.0, T + 1)
    err = lambda T: jnp.float32(0.0)         # noqa: E731
    status = health_loop(step, err, jnp.zeros(2) + 0.5, 20, 0.0).status
    assert status.describe() == "DIVERGED"


def test_rescue_restarts_with_escalated_scale():
    """A step that overflows at scale 1 but behaves at scale >= 2 must be
    rescued: restart from the last healthy iterate, escalated scale."""
    def step(T, scale):
        return jnp.where(scale < 2.0, jnp.inf, T + 1.0)
    err = lambda T: jnp.float32(0.0)         # noqa: E731
    T, errs, n_iters, conv, status, _ = health_loop(
        step, err, jnp.zeros(2), 10, 0.0, scaled_step=True, max_rescues=2)
    assert status.describe() == "MAXITER"    # healthy after rescue
    assert int(status.n_rescues) == 1
    assert int(status.fail_iter) == 0        # the hiccup is still recorded
    # 10 budget iterations, 1 consumed by the rescue -> 9 real steps
    np.testing.assert_allclose(np.asarray(T), 9.0)


def test_rescue_exhaustion_diverges():
    step = lambda T, scale: jnp.full_like(T, jnp.nan)    # noqa: E731
    err = lambda T: jnp.float32(0.0)                     # noqa: E731
    status = health_loop(step, err, jnp.ones(2), 10, 0.0,
                         scaled_step=True, max_rescues=2).status
    assert status.describe() == "DIVERGED"
    assert int(status.n_rescues) == 2
    assert int(status.fail_iter) == 0


def test_zero_budget_loop():
    T, errs, n_iters, conv, status, _ = health_loop(
        lambda T: T, lambda T: jnp.float32(0), jnp.ones(2), 0, 1e-6)
    assert int(n_iters) == 0 and not bool(conv)
    assert status.describe() == "MAXITER"


# ---------------------------------------------------------------------------
# FaultSpec
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec(kind="bogus")
    with pytest.raises(ValueError, match="site"):
        FaultSpec(site="bogus")


def test_fault_spec_fires_only_at_configured_iteration():
    f = FaultSpec(at_iter=3, kind="nan")
    x = jnp.ones(4)
    assert np.all(np.isfinite(np.asarray(f.apply(x, jnp.int32(2)))))
    assert np.all(np.isnan(np.asarray(f.apply(x, jnp.int32(3)))))
    assert np.all(np.isfinite(np.asarray(f.apply(x, jnp.int32(4)))))
    fp = FaultSpec(at_iter=3, kind="inf", persistent=True)
    assert np.all(np.isinf(np.asarray(fp.apply(x, jnp.int32(7)))))
    disarmed = FaultSpec(at_iter=-1, kind="nan")
    assert np.all(np.isfinite(np.asarray(disarmed.apply(x, jnp.int32(0)))))


def test_fault_at_iter_is_dynamic_leaf():
    t1 = jax.tree_util.tree_flatten(FaultSpec(at_iter=1, kind="nan"))[1]
    t2 = jax.tree_util.tree_flatten(FaultSpec(at_iter=9, kind="nan"))[1]
    assert t1 == t2                          # re-aiming never retraces
    t3 = jax.tree_util.tree_flatten(FaultSpec(at_iter=1, kind="inf"))[1]
    assert t1 != t3                          # kind selects code: static


# ---------------------------------------------------------------------------
# per-solver detection (the injected-fault matrix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["dense_gw", "spar_gw", "grid_gw",
                                  "lowrank_gw"])
@pytest.mark.parametrize("kind", ["nan", "inf"])
@pytest.mark.parametrize("site", ["iterate", "cost"])
def test_solver_reports_diverged_at_fault_iteration(name, kind, site):
    solver = _faulted(_fast_configs()[name], at_iter=2, kind=kind, site=site,
                      persistent=True)
    out = solve(_problem(), solver, key=KEY)
    assert out.status.describe() == "DIVERGED"
    assert int(out.status.fail_iter) == 2
    # the returned coupling is the last healthy iterate, never the poison
    dense = out.coupling_dense(N, N)
    assert np.all(np.isfinite(np.asarray(dense)))
    assert np.all(np.isfinite(np.asarray(out.errors[:2])))


def test_quantized_inherits_base_divergence():
    base = _faulted(DenseGWSolver(tol=1e-6, inner_tol=1e-8), at_iter=2,
                    kind="nan", persistent=True)
    out = solve(_problem(), QuantizedGWSolver(base=base), key=KEY)
    assert out.status.describe() == "DIVERGED"
    assert int(out.status.fail_iter) == 2


def test_quantized_polish_divergence_escalates():
    solver = _faulted(QuantizedGWSolver(polish_iters=3), at_iter=1,
                      kind="nan", persistent=True)
    out = solve(_problem(), solver, key=KEY)
    assert out.status.describe() == "DIVERGED"


def test_solver_rescue_recovers_transient_fault():
    """A once-off fault is absorbed by one ε-rescue restart: the solve
    finishes healthy, records the rescue, and stays finite."""
    solver = dataclasses.replace(
        DenseGWSolver(tol=1e-6, inner_tol=1e-8, outer_iters=10),
        max_rescues=2, fault=FaultSpec(at_iter=3, kind="nan"))
    out = solve(_problem(), solver, key=KEY)
    assert out.status.describe() in ("CONVERGED", "MAXITER")
    assert int(out.status.n_rescues) == 1
    assert int(out.status.fail_iter) == 3    # provenance survives recovery
    assert np.all(np.isfinite(np.asarray(out.coupling)))


def test_rescue_is_bitwise_deterministic():
    """Rescue draws no new randomness: two recovered solves are equal."""
    solver = dataclasses.replace(
        SparGWSolver(s=8 * N, outer_iters=8, inner_tol=1e-8),
        max_rescues=2, fault=FaultSpec(at_iter=2, kind="inf"))
    o1 = solve(_problem(), solver, key=KEY)
    o2 = solve(_problem(), solver, key=KEY)
    assert int(o1.status.n_rescues) == 1
    assert _trees_equal(o1, o2)


# ---------------------------------------------------------------------------
# vmap per-lane independence
# ---------------------------------------------------------------------------

def test_vmap_poisoned_lane_does_not_corrupt_peers():
    """One poisoned lane in a stacked solve: peers must return bitwise
    exactly their solo results; the poisoned lane alone reports DIVERGED."""
    probs = [_problem(seed=s) for s in range(4)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *probs)
    at = jnp.array([-1, 2, -1, -1], jnp.int32)   # poison lane 1 only
    base = DenseGWSolver(tol=1e-6, inner_tol=1e-8, outer_iters=10)

    def run_one(p, at_iter):
        s = dataclasses.replace(base, max_rescues=0,
                                fault=FaultSpec(at_iter=at_iter, kind="nan"))
        return s.run(p, None)

    outs = jax.jit(jax.vmap(run_one))(stacked, at)
    assert outs.status.describe() == ["MAXITER", "DIVERGED", "MAXITER",
                                      "MAXITER"]
    np.testing.assert_array_equal(np.asarray(outs.status.fail_iter),
                                  [-1, 2, -1, -1])
    clean = dataclasses.replace(base, max_rescues=0,
                                fault=FaultSpec(at_iter=-1, kind="nan"))
    for lane in (0, 2, 3):
        solo = solve(probs[lane], clean)
        np.testing.assert_array_equal(np.asarray(outs.coupling)[lane],
                                      np.asarray(solo.coupling))
    assert np.all(np.isfinite(np.asarray(outs.coupling)[1]))


# ---------------------------------------------------------------------------
# solve() front door: key validation, on_failure modes, fallback chain
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["spar_gw", "grid_gw", "quantized_gw",
                                  "lowrank_gw"])
def test_solve_requires_key_eagerly(name):
    with pytest.raises(ValueError, match="PRNG key"):
        solve(_problem(), _fast_configs()[name], key=None)


def test_solve_dense_needs_no_key():
    out = solve(_problem(), DenseGWSolver(outer_iters=3, inner_iters=10))
    assert np.isfinite(float(out.value))


def test_on_failure_raise():
    solver = _faulted(DenseGWSolver(outer_iters=5), at_iter=1, kind="nan",
                      persistent=True)
    with pytest.raises(SolveDivergedError, match="DIVERGED") as exc_info:
        solve(_problem(), solver, on_failure="raise")
    assert exc_info.value.output.status.describe() == "DIVERGED"


def test_on_failure_rejects_unknown_mode():
    with pytest.raises(ValueError, match="on_failure"):
        solve(_problem(), DenseGWSolver(), on_failure="explode")


def test_fallback_returns_finite_feasible_coupling():
    solver = _faulted(SparGWSolver(s=8 * N, outer_iters=8), at_iter=1,
                      kind="nan", persistent=True)
    out = solve(_problem(), solver, key=KEY, on_failure="fallback")
    assert out.status.describe() != "DIVERGED"
    dense = out.coupling_dense(N, N)
    assert np.all(np.isfinite(np.asarray(dense)))
    # feasibility: the recovered coupling's marginals approximate (a, b)
    a = np.asarray(_problem().geom_x.weights)
    assert np.sum(np.abs(np.asarray(dense).sum(1) - a)) < 0.2
    assert np.sum(np.abs(np.asarray(dense).sum(0) - a)) < 0.2


def test_fallback_is_bitwise_reproducible():
    """fold_in(key, attempt) re-keying: the whole recovery path is
    deterministic end to end."""
    solver = _faulted(SparGWSolver(s=8 * N, outer_iters=8), at_iter=1,
                      kind="nan", persistent=True)
    o1 = solve(_problem(), solver, key=KEY, on_failure="fallback")
    o2 = solve(_problem(), solver, key=KEY, on_failure="fallback")
    assert _trees_equal(o1, o2)


def test_fallback_rekeys_with_fold_in():
    """The first fallback attempt must see fold_in(key, 1), not the raw
    key — a regression guard on deterministic retry PRNG."""
    prob = _problem()
    solver = _faulted(SparGWSolver(s=8 * N, outer_iters=8), at_iter=0,
                      kind="nan", persistent=True)
    out = solve(prob, solver, key=KEY, on_failure="fallback")
    chain = fallback_chain(prob, exclude=("spar_gw",))
    expected = solve(prob, chain[0], key=jax.random.fold_in(KEY, 1))
    assert _trees_equal(out, expected)


def test_fallback_chain_eligibility_gating():
    small = _problem()
    names = [type(s).name for s in fallback_chain(small)]
    assert names == ["lowrank_gw", "quantized_gw", "spar_gw", "dense_gw"]
    # unbalanced problems are ineligible for lowrank
    unbal = _problem(lam=1.0)
    names = [type(s).name for s in fallback_chain(unbal)]
    assert "lowrank_gw" not in names
    # l1 loss is not decomposable -> no lowrank either
    names = [type(s).name for s in fallback_chain(_problem(loss="l1"))]
    assert "lowrank_gw" not in names
    # without a key only dense remains
    names = [type(s).name for s in fallback_chain(small,
                                                  key_available=False)]
    assert names == ["dense_gw"]
    # exclusion drops the already-tried rung
    names = [type(s).name for s in fallback_chain(small,
                                                  exclude=("spar_gw",))]
    assert "spar_gw" not in names


def test_on_failure_under_tracing_raises_clear_error():
    solver = DenseGWSolver(outer_iters=2, inner_iters=5)
    prob = _problem()

    def traced(p):
        return solve(p, solver, on_failure="fallback", validate=False)

    with pytest.raises(ValueError, match="jit/vmap"):
        jax.jit(traced)(prob)


# ---------------------------------------------------------------------------
# status lattice / output plumbing
# ---------------------------------------------------------------------------

def test_status_join_prefers_worse_code():
    ok = SolveStatus.healthy(CONVERGED)
    bad = SolveStatus(code=jnp.int32(DIVERGED), fail_iter=jnp.int32(4),
                      last_err=jnp.float32(0.5), n_rescues=jnp.int32(1))
    j = ok.join(bad)
    assert j.describe() == "DIVERGED"
    assert int(j.fail_iter) == 4
    assert int(j.n_rescues) == 1
    assert ok.join(SolveStatus.healthy(MAXITER)).describe() == "MAXITER"


def test_status_codes_are_severity_ordered():
    assert CONVERGED < MAXITER < STALLED < DIVERGED


def test_every_solver_returns_status():
    for name, solver in _fast_configs().items():
        out = solve(_problem(), solver, key=KEY)
        assert out.status is not None, name
        assert out.status.describe() in ("CONVERGED", "MAXITER", "STALLED"), \
            name


def test_output_status_survives_pytree_roundtrip():
    out = solve(_problem(), DenseGWSolver(outer_iters=3, inner_iters=10))
    leaves, treedef = jax.tree_util.tree_flatten(out)
    out2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert out2.status.describe() == out.status.describe()


# ---------------------------------------------------------------------------
# tiny-ε overflow paths (satellite: never silent NaN)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eps", [1e-4, 1e-5])
@pytest.mark.parametrize("stable", [True, False])
def test_tiny_epsilon_concentrated_marginals(eps, stable):
    """ε ≤ 1e-4 with near-degenerate marginals: either the solve stays
    finite or it reports DIVERGED — silent NaN/zero couplings are the bug
    class this layer exists to kill."""
    prob = _problem(concentrated=True)
    solver = DenseGWSolver(epsilon=eps, stable=stable, outer_iters=8,
                           inner_iters=50, max_rescues=0)
    out = solve(prob, solver)
    code = out.status.describe()
    if code != "DIVERGED":
        T = np.asarray(out.coupling)
        assert np.all(np.isfinite(T))
        assert T.sum() > 1e-6                # no silent mass collapse
        assert np.isfinite(float(out.value))


@pytest.mark.parametrize("eps", [1e-4, 1e-5])
def test_tiny_epsilon_rescue_recovers_plain_domain(eps):
    """The plain-domain kernel exp(-C/ε) underflows to zero mass at tiny
    ε; ε-doubling rescue must recover a finite coupling in-jit."""
    prob = _problem(concentrated=True)
    solver = DenseGWSolver(epsilon=eps, stable=False, outer_iters=8,
                           inner_iters=50, max_rescues=8)
    out = solve(prob, solver)
    if out.status.describe() != "DIVERGED":
        assert np.all(np.isfinite(np.asarray(out.coupling)))
        assert int(out.status.n_rescues) >= 0


@pytest.mark.parametrize("eps", [1e-4, 1e-5])
def test_tiny_epsilon_sparse_sinkhorn_finite(eps):
    """core.sinkhorn sparse log-domain path at tiny ε stays finite."""
    from repro.core.sinkhorn import sparse_sinkhorn_logdomain
    n, s = 16, 64
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    rows = jax.random.randint(k1, (s,), 0, n)
    cols = jax.random.randint(k2, (s,), 0, n)
    C = jax.random.uniform(k3, (s,)) * 4.0
    a = jnp.full((n,), 1e-4).at[0].set(1.0 - (n - 1) * 1e-4)
    b = jnp.ones(n) / n
    T = sparse_sinkhorn_logdomain(a, b, rows, cols, -C / eps, n, n, 200,
                                  tol=1e-9)
    assert np.all(np.isfinite(np.asarray(T)))


@pytest.mark.parametrize("eps", [1e-4, 1e-5])
def test_tiny_epsilon_lr_dykstra_finite(eps):
    """LR-Dykstra fed mirror-step kernels built at tiny ε (huge exponent
    ratios) must return finite feasible factors."""
    m = n = 16
    r = 4
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    a = jnp.full((m,), 1e-4).at[0].set(1.0 - (m - 1) * 1e-4)
    b = jnp.ones(n) / n
    # kernels spanning e^{±1/ε}-ish dynamic range, clipped to f32-finite
    K1 = jnp.clip(jnp.exp(jax.random.normal(k1, (m, r)) / jnp.sqrt(eps)),
                  1e-30, 1e30)
    K2 = jnp.clip(jnp.exp(jax.random.normal(k2, (n, r)) / jnp.sqrt(eps)),
                  1e-30, 1e30)
    k3 = jnp.full((r,), 1.0 / r)
    Q, R, g = lr_dykstra(K1, K2, k3, a, b, 1e-10, 200, 1e-8)
    for arr in (Q, R, g):
        assert np.all(np.isfinite(np.asarray(arr)))
    np.testing.assert_allclose(np.asarray(Q.sum(1)), np.asarray(a),
                               atol=1e-2)
    np.testing.assert_allclose(np.asarray(R.sum(1)), np.asarray(b),
                               atol=1e-2)


# ---------------------------------------------------------------------------
# benchmark harness resilience (satellite: run.py survives failing solvers)
# ---------------------------------------------------------------------------

def test_run_py_records_failure_row(tmp_path, monkeypatch, capsys):
    import json
    import sys as _sys
    _sys.path.insert(0, ".")
    try:
        from benchmarks import run as bench_run

        def boom(name, **kw):
            raise RuntimeError(f"synthetic failure in {name}")

        monkeypatch.setattr("benchmarks.common.bench_solver", boom)
        json_path = str(tmp_path / "bench.json")
        with pytest.raises(SystemExit):
            bench_run.run_solver_mode(["dense_gw"], n=16, loss="l2", reps=1,
                                      json_path=json_path)
        rows = json.load(open(json_path))["results"]
        assert rows and rows[0]["status"] == "failed"
        assert "synthetic failure" in rows[0]["error"]
    finally:
        _sys.path.remove(".")
