"""Differentiable-GW suite (src/repro/diff/, DESIGN.md §11).

Ground truth comes from two independent references:

* **finite differences** of the solver's own value (x64, directional,
  central) — validates the Danskin envelope against the actual
  optimization landscape;
* **unrolled autodiff** (diff/unrolled.py) — backprop through every
  iteration of a faithful lax.scan replay; exact for the fixed-budget
  value function regardless of convergence.

Gradient quality is gated on convergence (an unconverged fixed point
breaks Danskin's premise), so the FD configs below run generous budgets
with tol=0/inner_tol=0; the measured rel errors are ~1e-6 (dense),
~1e-9 (lowrank, anchors init), ~1e-5 (spar vs unrolled, x64) and
~5e-4 (spar vs f32 FD) — the assertions leave real headroom.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.api.geometry import Geometry
from repro.api.problem import QuadraticProblem
from repro.api.solvers import DenseGWSolver, SparGWSolver
from repro.diff import envelope_loop, fgw_loss, gw_barycenter, gw_loss, \
    quadratic_loss
from repro.diff.unrolled import unrolled_value
from repro.lowrank.solver import LowRankGWSolver

REL_TOL = 1e-3


# ---------------------------------------------------------------- helpers

def _clouds(n, m, pert, seed):
    """Near-isometric pair: y = rotation of x + noise, truncated to m.

    Well-conditioned on purpose — the FD assertions need the solver to
    actually reach its fixed point inside the test budget.
    """
    key = jax.random.PRNGKey(seed)
    kx, kp = jax.random.split(key)
    x = jax.random.normal(kx, (n, 2))
    th = 0.7
    R = jnp.array([[jnp.cos(th), -jnp.sin(th)], [jnp.sin(th), jnp.cos(th)]])
    y = (x @ R.T + pert * jax.random.normal(kp, (n, 2)))[:m]
    return x, y


def _sqdist(z):
    s = jnp.sum(z * z, axis=1)
    return jnp.maximum(s[:, None] + s[None, :] - 2.0 * z @ z.T, 0.0)


def _uniform(k):
    return jnp.full((k,), 1.0 / k)


def _fd(f, x, d, h=1e-6):
    """Central directional derivative of scalar f at x along d."""
    return float((f(x + h * d) - f(x - h * d)) / (2.0 * h))


def _rel(u, v):
    return abs(u - v) / max(abs(u), abs(v), 1e-12)


def _sym_dir(rng, n):
    D = rng.standard_normal((n, n))
    return jnp.asarray((D + D.T) / 2.0)


# ------------------------------------------------- dense: FD + unrolled

class TestDenseGradient:
    """Envelope gradient of the dense prox solve vs FD and unrolling."""

    def _setup(self):
        n = 10
        x, y = _clouds(n, n, 0.1, 0)
        a, b = _uniform(n), _uniform(n)
        Cx, Cy = _sqdist(x), _sqdist(y)
        solver = DenseGWSolver(epsilon=2e-2, outer_iters=300,
                               inner_iters=400, tol=0.0, inner_tol=0.0)

        def value(Cx_):
            p = QuadraticProblem(Geometry(Cx_, a, validate=False),
                                 Geometry(Cy, b, validate=False),
                                 validate=False)
            return solver.run(p).value

        def value_unrolled(Cx_):
            p = QuadraticProblem(Geometry(Cx_, a, validate=False),
                                 Geometry(Cy, b, validate=False),
                                 validate=False)
            return unrolled_value(p, solver)

        return Cx, value, value_unrolled, n

    def test_matches_fd_and_unrolled(self):
        with enable_x64():
            Cx, value, value_unrolled, n = self._setup()
            D = _sym_dir(np.random.default_rng(0), n)
            an = float(jnp.sum(jax.grad(value)(Cx) * D))
            an_unrolled = float(jnp.sum(jax.grad(value_unrolled)(Cx) * D))
            fd = _fd(value, Cx, D)
            assert _rel(an, fd) <= REL_TOL, (an, fd)
            assert _rel(an, an_unrolled) <= REL_TOL, (an, an_unrolled)

    def test_unrolled_forward_matches_solver(self):
        # faithfulness contract: same budget, same trajectory
        with enable_x64():
            Cx, value, value_unrolled, _ = self._setup()
            np.testing.assert_allclose(float(value(Cx)),
                                       float(value_unrolled(Cx)), rtol=1e-10)


# ---------------------------------------------- spar: unrolled + FD

class TestSparGradient:
    """spar_gw: the envelope vs backprop through the *actual*
    ``_spar_pga_step`` (bitwise-identical forward trajectory).

    Two regimes, one per reference:

    * **unrolled parity** runs in x64 at a small budget — the measured
      gap (~1e-5) is the Danskin residual of the not-yet-settled fixed
      point, and x64 keeps the 400-step backprop accumulation from
      overflowing (the same unrolled backward is NaN in f32);
    * **FD** runs in f32 at the full production budget: the importance
      sampler's index draws shift under x64 (the importance weights
      change in the low bits), so x64 FD compares *different sparse
      patterns* and stalls at ~3e-2, while converged f32 reaches ~8e-4.
    """

    def _setup(self, outer, inner):
        n, m = 14, 11
        x, y = _clouds(n, m, 0.25, 1)
        a, b = _uniform(n), _uniform(m)
        # /10: keeps the inner Sinkhorn convergent at ε = 5e-2
        Cx, Cy = _sqdist(x) / 10.0, _sqdist(y) / 10.0
        key = jax.random.PRNGKey(5)
        solver = SparGWSolver(epsilon=5e-2, s=16 * n, outer_iters=outer,
                              inner_iters=inner, tol=0.0, inner_tol=0.0)

        def value(Cx_):
            p = QuadraticProblem(Geometry(Cx_, a, validate=False),
                                 Geometry(Cy, b, validate=False),
                                 validate=False)
            return solver.run(p, key).value

        def value_unrolled(Cx_):
            p = QuadraticProblem(Geometry(Cx_, a, validate=False),
                                 Geometry(Cy, b, validate=False),
                                 validate=False)
            return unrolled_value(p, solver, key)

        return Cx, value, value_unrolled, n

    def test_matches_unrolled(self):
        with enable_x64():
            Cx, value, value_unrolled, n = self._setup(100, 300)
            D = _sym_dir(np.random.default_rng(1), n)
            an = float(jnp.sum(jax.grad(value)(Cx) * D))
            an_unrolled = float(jnp.sum(jax.grad(value_unrolled)(Cx) * D))
            assert _rel(an, an_unrolled) <= REL_TOL, (an, an_unrolled)

    def test_matches_fd(self):
        Cx, value, _, n = self._setup(400, 1000)
        D = _sym_dir(np.random.default_rng(1), n).astype(jnp.float32)
        an = float(jnp.sum(jax.grad(value)(Cx) * D))
        # large h: the value has an f32 noise floor, and FD noise
        # scales as 1/h (measured rel 5e-4 at h=5e-3, vs 8e-4 at 1e-3)
        fd = _fd(jax.jit(value), Cx, D, h=5e-3)
        assert _rel(an, fd) <= 2e-3, (an, fd)

    def test_unrolled_forward_matches_solver(self):
        with enable_x64():
            Cx, value, value_unrolled, _ = self._setup(100, 300)
            np.testing.assert_allclose(float(value(Cx)),
                                       float(value_unrolled(Cx)), rtol=1e-10)

    def test_rejects_inner_tol(self):
        solver = SparGWSolver(inner_tol=1e-5)
        x, y = _clouds(8, 8, 0.2, 0)
        p = QuadraticProblem(Geometry.from_points(x, _uniform(8)),
                             Geometry.from_points(y, _uniform(8)))
        with pytest.raises(ValueError, match="inner_tol"):
            unrolled_value(p, solver, jax.random.PRNGKey(0))


# -------------------------------------------- lowrank: FD + unrolled

class TestLowRankGradient:
    def _setup(self, outer=600):
        n = 11
        x, y = _clouds(n, n, 0.25, 3)
        a, b = _uniform(n), _uniform(n)
        key = jax.random.PRNGKey(7)
        solver = LowRankGWSolver(rank=3, outer_iters=outer, inner_iters=150,
                                 tol=0.0, inner_tol=0.0, init="anchors")

        def value(x_):
            p = QuadraticProblem(Geometry.from_points(x_, a, validate=False),
                                 Geometry.from_points(y, b, validate=False),
                                 validate=False)
            return solver.run(p, key).value

        def value_unrolled(x_):
            p = QuadraticProblem(Geometry.from_points(x_, a, validate=False),
                                 Geometry.from_points(y, b, validate=False),
                                 validate=False)
            return unrolled_value(p, solver, key)

        return x, value, value_unrolled

    def test_matches_fd_and_unrolled(self):
        with enable_x64():
            x, value, value_unrolled = self._setup()
            D = jnp.asarray(np.random.default_rng(2).standard_normal(x.shape))
            an = float(jnp.sum(jax.grad(value)(x) * D))
            an_unrolled = float(jnp.sum(jax.grad(value_unrolled)(x) * D))
            fd = _fd(value, x, D)
            assert _rel(an, fd) <= REL_TOL, (an, fd)
            assert _rel(an, an_unrolled) <= REL_TOL, (an, an_unrolled)

    def test_grad_never_materializes_mn(self):
        """The whole grad jaxpr — anchors init, MD loop, value, backward
        contraction — must never hold an m×n (or n×m) array."""
        m, n = 37, 41
        x, y = _clouds(m, m, 0.2, 0)[0], _clouds(n, n, 0.2, 1)[0]
        a, b = _uniform(m), _uniform(n)
        solver = LowRankGWSolver(rank=3, outer_iters=5, inner_iters=8,
                                 init="anchors")

        def value(x_):
            p = QuadraticProblem(Geometry.from_points(x_, a, validate=False),
                                 Geometry.from_points(y, b, validate=False),
                                 validate=False)
            return solver.run(p, jax.random.PRNGKey(0)).value

        jaxpr = jax.make_jaxpr(jax.grad(value))(x)
        bad = [shape for shape in _all_shapes(jaxpr.jaxpr)
               if (m, n) == shape[-2:] or (n, m) == shape[-2:]]
        assert not bad, f"m×n avals in grad jaxpr: {bad[:5]}"


def _all_shapes(jaxpr):
    """Every aval shape in a jaxpr, recursing into sub-jaxprs (scan,
    custom_vjp calls, closed calls...)."""
    for v in (*jaxpr.invars, *jaxpr.constvars, *jaxpr.outvars):
        if hasattr(v, "aval") and hasattr(v.aval, "shape"):
            yield tuple(v.aval.shape)
    for eqn in jaxpr.eqns:
        for v in (*eqn.invars, *eqn.outvars):
            if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                yield tuple(v.aval.shape)
        for val in eqn.params.values():
            yield from _shapes_in(val)


def _shapes_in(val):
    if hasattr(val, "jaxpr"):                      # ClosedJaxpr
        yield from _all_shapes(val.jaxpr)
    elif hasattr(val, "eqns"):                     # raw Jaxpr
        yield from _all_shapes(val)
    elif isinstance(val, (tuple, list)):
        for item in val:
            yield from _shapes_in(item)


# ------------------------------------------------ composition: vmap/jit

class TestComposition:
    def _loss(self):
        n = 9
        _, y = _clouds(n, n, 0.2, 4)
        solver = DenseGWSolver(epsilon=5e-2, outer_iters=40, inner_iters=60,
                               tol=0.0, inner_tol=0.0)

        def f(x_):
            return gw_loss(x_, y, solver=solver)
        return f, n

    def _batch(self, n, B=3):
        return jnp.stack([_clouds(n, n, 0.3, 10 + i)[0] for i in range(B)])

    def test_vmap_of_grad_matches_stacked(self):
        f, n = self._loss()
        xs = self._batch(n)
        batched = jax.vmap(jax.grad(f))(xs)
        single = jnp.stack([jax.grad(f)(x) for x in xs])
        np.testing.assert_allclose(np.asarray(batched), np.asarray(single),
                                   rtol=2e-4, atol=1e-6)

    def test_grad_of_vmap_matches_stacked(self):
        f, n = self._loss()
        xs = self._batch(n)
        g = jax.grad(lambda xs_: jnp.sum(jax.vmap(f)(xs_)))(xs)
        single = jnp.stack([jax.grad(f)(x) for x in xs])
        np.testing.assert_allclose(np.asarray(g), np.asarray(single),
                                   rtol=2e-4, atol=1e-6)

    def test_jit_grad_matches_eager(self):
        f, n = self._loss()
        x = _clouds(n, n, 0.3, 20)[0]
        eager = jax.grad(f)(x)
        jitted = jax.jit(jax.grad(f))(x)
        np.testing.assert_allclose(np.asarray(jitted), np.asarray(eager),
                                   rtol=1e-4, atol=1e-6)
        assert bool(jnp.all(jnp.isfinite(jitted)))

    def test_grad_with_trace_and_health(self):
        # envelope must coexist with trace buffers and rescue machinery
        n = 8
        x, y = _clouds(n, n, 0.2, 6)
        solver = DenseGWSolver(epsilon=5e-2, outer_iters=30, inner_iters=40,
                               trace=True, max_rescues=2)

        def f(x_):
            p = QuadraticProblem(Geometry.from_points(x_, _uniform(n)),
                                 Geometry.from_points(y, _uniform(n)),
                                 validate=False)
            return quadratic_loss(p, solver)

        g = jax.grad(f)(x)
        assert bool(jnp.all(jnp.isfinite(g)))


# ------------------------------------------- fused / marginals / lam

class TestFusedAndMarginals:
    def test_fgw_feature_and_alpha_grads_match_fd(self):
        with enable_x64():
            n = 10
            x, y = _clouds(n, n, 0.1, 0)
            kf = jax.random.PRNGKey(9)
            fx = jax.random.normal(kf, (n, 3))
            fy = jax.random.normal(jax.random.fold_in(kf, 1), (n, 3))
            # ε = 5e-2 (not 2e-2): the fused fixed point settles an
            # order of magnitude faster, rel ~5e-4 inside this budget
            solver = DenseGWSolver(epsilon=5e-2, outer_iters=300,
                                   inner_iters=400, tol=0.0, inner_tol=0.0)

            def f(fx_, alpha):
                return fgw_loss(x, y, fx_, fy, fused_penalty=alpha,
                                solver=solver)

            D = jnp.asarray(np.random.default_rng(3).standard_normal(
                fx.shape))
            gfx, galpha = jax.grad(f, argnums=(0, 1))(fx, 0.6)
            an_f = float(jnp.sum(gfx * D))
            fd_f = _fd(lambda z: f(z, 0.6), fx, D)
            fd_a = _fd(lambda t: f(fx, t), jnp.asarray(0.6),
                       jnp.asarray(1.0))
            assert _rel(an_f, fd_f) <= REL_TOL, (an_f, fd_f)
            assert _rel(float(galpha), fd_a) <= REL_TOL, (galpha, fd_a)

    def test_unbalanced_marginal_and_lam_grads_match_fd(self):
        """Unbalanced marginals/lam are *live* envelope paths (the KL
        penalties read (a, b) in the value recompute): exact, FD to
        ~1e-10 at any budget."""
        with enable_x64():
            n = 10
            x, y = _clouds(n, n, 0.4, 11)
            Cx, Cy = _sqdist(x), _sqdist(y)
            b = _uniform(n)
            solver = DenseGWSolver(epsilon=5e-2, outer_iters=300,
                                   inner_iters=400, tol=0.0, inner_tol=0.0)

            def f(a_, lam):
                p = QuadraticProblem(Geometry(Cx, a_, validate=False),
                                     Geometry(Cy, b, validate=False),
                                     lam=lam, validate=False)
                return quadratic_loss(p, solver)

            a = _uniform(n)
            da = jnp.asarray(
                np.random.default_rng(5).standard_normal(n) * 0.3)
            ga, glam = jax.grad(f, argnums=(0, 1))(a, jnp.asarray(1.0))
            an_a = float(jnp.sum(ga * da))
            fd_a = _fd(lambda a_: f(a_, 1.0), a, da)
            fd_l = _fd(lambda t: f(a, t), jnp.asarray(1.0),
                       jnp.asarray(1.0))
            assert _rel(an_a, fd_a) <= REL_TOL, (an_a, fd_a)
            assert _rel(float(glam), fd_l) <= REL_TOL, (glam, fd_l)

    def test_balanced_marginal_certificate(self):
        """Balanced marginal_grads: primal-zero (value bit-unchanged)
        and a finite nonzero zero-sum certificate direction. FD parity
        is NOT asserted — at sparse prox fixed points the computed
        value's marginal sensitivity is support-jump dominated (see
        DESIGN.md §11); the unbalanced path above is the exact one."""
        n = 10
        x, y = _clouds(n, n, 0.6, 11)
        Cx, Cy = _sqdist(x), _sqdist(y)
        a, b = _uniform(n), _uniform(n)
        solver = DenseGWSolver(epsilon=5e-2, outer_iters=100,
                               inner_iters=150, tol=0.0, inner_tol=0.0)

        def f(a_, with_duals):
            p = QuadraticProblem(Geometry(Cx, a_, validate=False),
                                 Geometry(Cy, b, validate=False),
                                 validate=False)
            return quadratic_loss(p, solver, marginal_grads=with_duals)

        np.testing.assert_allclose(float(f(a, True)), float(f(a, False)),
                                   rtol=1e-6)
        ga = jax.grad(lambda a_: f(a_, True))(a)
        assert bool(jnp.all(jnp.isfinite(ga)))
        # a nonzero certificate, and zero along the mass gauge direction
        centered = ga - jnp.mean(ga)
        assert float(jnp.sum(jnp.abs(centered))) > 0.0

    def test_marginal_grads_guardrails(self):
        n = 6
        x, y = _clouds(n, n, 0.2, 0)
        p = QuadraticProblem(Geometry.from_points(x, _uniform(n)),
                             Geometry.from_points(y, _uniform(n)))
        with pytest.raises(ValueError, match="prox"):
            quadratic_loss(p, DenseGWSolver(reg="ent"), marginal_grads=True)
        p_unbal = QuadraticProblem(Geometry.from_points(x, _uniform(n)),
                                   Geometry.from_points(y, _uniform(n)),
                                   lam=1.0)
        with pytest.raises(ValueError, match="balanced"):
            quadratic_loss(p_unbal, DenseGWSolver(),
                           marginal_grads=True)

    def test_unbalanced_grads_finite(self):
        # unbalanced marginal/lam gradients flow through the KL terms
        n = 8
        x, y = _clouds(n, n, 0.2, 7)
        Cx, Cy = _sqdist(x), _sqdist(y)
        solver = DenseGWSolver(epsilon=5e-2, outer_iters=40, inner_iters=60)

        def f(a_, lam):
            p = QuadraticProblem(Geometry(Cx, a_, validate=False),
                                 Geometry(Cy, _uniform(n), validate=False),
                                 lam=lam, validate=False)
            return quadratic_loss(p, solver)

        ga, glam = jax.grad(f, argnums=(0, 1))(_uniform(n), jnp.asarray(1.0))
        assert bool(jnp.all(jnp.isfinite(ga)))
        assert bool(jnp.isfinite(glam))
        assert float(jnp.sum(jnp.abs(ga))) > 0.0


# ------------------------------------------------------- barycenter

class TestBarycenter:
    def test_descends_and_is_finite(self):
        x1, _ = _clouds(16, 16, 0.1, 0)
        x2, _ = _clouds(14, 14, 0.1, 1)
        solver = DenseGWSolver(epsilon=5e-2, outer_iters=60, inner_iters=80,
                               tol=0.0, inner_tol=0.0)
        res = gw_barycenter([x1, x2], n_points=12, key=jax.random.PRNGKey(2),
                            solver=solver, steps=12, lr=0.05)
        objs = np.asarray(res.objectives)
        assert res.points.shape == (12, 2)
        assert np.all(np.isfinite(objs))
        assert np.all(np.isfinite(np.asarray(res.grad_norms)))
        assert objs[-1] < objs[0], objs

    def test_needs_dim_for_cost_inputs(self):
        C = _sqdist(_clouds(8, 8, 0.2, 0)[0])
        g = Geometry(C, _uniform(8), validate=False)
        with pytest.raises(ValueError, match="dim"):
            gw_barycenter([g, g], n_points=6, key=jax.random.PRNGKey(0),
                          steps=1)


# ----------------------------------------------- learned ground cost

class TestLearnedCost:
    def test_mlp_ground_cost_trains(self):
        """fgw_loss with model-produced features: grads reach the MLP
        params and a few AdamW steps reduce the loss (worked example in
        EXPERIMENTS.md §PR10)."""
        from repro.models.layers import mlp, mlp_params
        from repro.models.module import Builder
        from repro.optim import adamw

        n = 10
        x, y = _clouds(n, n, 0.15, 8)
        params = mlp_params(Builder("init", jax.random.PRNGKey(0)), 2, 8)
        solver = DenseGWSolver(epsilon=5e-2, outer_iters=60, inner_iters=80,
                               tol=0.0, inner_tol=0.0)

        def loss_fn(p):
            return fgw_loss(x, y, mlp(p, x), mlp(p, y), fused_penalty=0.5,
                            solver=solver)

        value_and_grad = jax.jit(jax.value_and_grad(loss_fn))
        opt = adamw.init(params)
        losses = []
        p = params
        for _ in range(6):
            value, grads = value_and_grad(p)
            losses.append(float(value))
            assert all(bool(jnp.all(jnp.isfinite(g)))
                       for g in jax.tree.leaves(grads))
            p, opt, _ = adamw.update(grads, opt, p, 3e-3, weight_decay=0.0)
        assert losses[-1] < losses[0], losses


# -------------------------------------------------- envelope plumbing

class TestEnvelopePlumbing:
    def test_primal_identical_to_health_loop(self):
        """The envelope is gradient-only: forward results must be
        leaf-for-leaf identical to calling health_loop directly."""
        from repro.health.loop import health_loop

        c = jnp.asarray([1.0, -2.0, 3.0])

        def step(T):
            return 0.5 * (T + c)

        def err(T):
            return jnp.sum(jnp.abs(T - c))

        T0 = jnp.zeros(3)
        ref = health_loop(step, err, T0, 50, 1e-6)
        env = envelope_loop(step, err, T0, 50, 1e-6)
        for r, e in zip(jax.tree.leaves(ref), jax.tree.leaves(env)):
            np.testing.assert_array_equal(np.asarray(r), np.asarray(e))

    def test_anchor_init_is_feasible(self):
        from repro.lowrank.init import anchor_init

        n, m, r = 23, 17, 4
        x, y = _clouds(n, n, 0.3, 9)[0], _clouds(m, m, 0.3, 10)[0]
        a, b = _uniform(n), _uniform(m)
        p = QuadraticProblem(Geometry.from_points(x, a, validate=False),
                             Geometry.from_points(y, b, validate=False),
                             validate=False)
        Q, R, g = anchor_init(jax.random.PRNGKey(0), p, r)
        np.testing.assert_allclose(np.asarray(Q.sum(axis=1)), np.asarray(a),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(R.sum(axis=1)), np.asarray(b),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(Q.sum(axis=0)),
                                   np.asarray(g), rtol=1e-5)
        # R's column sums inherit the anchor coupling's residual marginal
        # error (tiny budgeted r×r solve) — Dykstra's first projection
        # absorbs it; just require it to be small
        np.testing.assert_allclose(np.asarray(R.sum(axis=0)),
                                   np.asarray(g), rtol=5e-2)
        assert float(Q.min()) > 0 and float(R.min()) > 0 and float(g.min()) > 0

    def test_lowrank_init_registry_guard(self):
        x, y = _clouds(8, 8, 0.2, 0)
        p = QuadraticProblem(Geometry.from_points(x, _uniform(8)),
                             Geometry.from_points(y, _uniform(8)))
        with pytest.raises(ValueError, match="init"):
            LowRankGWSolver(init="bogus").run(p, jax.random.PRNGKey(0))
