"""Serving layer (repro.serve): bucketing, padding inertness, the
content-hash geometry cache, batched lane isolation, per-request
fallback, and server observability."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import DenseGWSolver, Geometry, QuadraticProblem
from repro.health import DIVERGED, STALLED, FaultSpec
from repro.serve import (
    DEFAULT_BUCKETS,
    PAD_WEIGHT,
    GeometryCache,
    GWServer,
    RequestResult,
    ServeConfig,
    batch_signature,
    bucket_for,
    next_pow2,
    pad_geometry,
    pad_problem,
    percentiles,
)
from repro.serve.batching import MIN_LANES

KEY = jax.random.PRNGKey(0)

BASE = DenseGWSolver(tol=1e-6, inner_tol=1e-8, outer_iters=10)
CLEAN = dataclasses.replace(BASE, max_rescues=0,
                            fault=FaultSpec(at_iter=-1, kind="nan"))
POISONED = dataclasses.replace(BASE, max_rescues=0,
                               fault=FaultSpec(at_iter=2, kind="nan"))


def _geom(seed: int, n: int, scale: float = 1.0) -> Geometry:
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 2)) * scale
    C = jnp.sqrt(jnp.sum((x[:, None] - x[None, :]) ** 2, -1))
    return Geometry(C, jnp.ones(n) / n)


def _problem(seed: int, m: int, n: int = None) -> QuadraticProblem:
    n = m if n is None else n
    return QuadraticProblem(_geom(seed, m), _geom(seed + 50, n, scale=1.2))


def _bits(tree_a, tree_b) -> bool:
    la, ta = jax.tree.flatten(tree_a)
    lb, tb = jax.tree.flatten(tree_b)
    return ta == tb and all(
        np.asarray(x).tobytes() == np.asarray(y).tobytes()
        for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# bucket policy
# ---------------------------------------------------------------------------

def test_bucket_for_picks_smallest_fitting_bucket():
    assert bucket_for(1) == 16
    assert bucket_for(16) == 16
    assert bucket_for(17) == 24
    assert bucket_for(100) == 128
    assert bucket_for(512) == 512


def test_bucket_for_beyond_largest_uses_next_pow2():
    assert bucket_for(513) == 1024
    assert bucket_for(2000) == 2048


def test_bucket_for_rejects_nonpositive():
    with pytest.raises(ValueError):
        bucket_for(0)


def test_next_pow2_has_min_lanes_floor():
    # width-1 stacks are forbidden: XLA lowers a degenerate batch-1
    # dot_general differently from every width >= 2 (and from eager), so
    # a width floor is what makes per-lane bits width-invariant
    assert MIN_LANES >= 2
    assert next_pow2(1) == MIN_LANES
    assert next_pow2(2) == 2
    assert next_pow2(3) == 4
    assert next_pow2(8) == 8
    assert next_pow2(9) == 16


# ---------------------------------------------------------------------------
# padding
# ---------------------------------------------------------------------------

def test_pad_geometry_shapes_and_values():
    g = _geom(0, 14)
    p = pad_geometry(g, 16)
    assert p.cost.shape == (16, 16) and p.weights.shape == (16,)
    np.testing.assert_array_equal(np.asarray(p.cost)[:14, :14],
                                  np.asarray(g.cost))
    assert np.all(np.asarray(p.cost)[14:, :] == 0.0)
    np.testing.assert_array_equal(np.asarray(p.weights)[:14],
                                  np.asarray(g.weights))
    assert np.all(np.asarray(p.weights)[14:] == np.float32(PAD_WEIGHT))


def test_pad_geometry_noop_at_size_and_rejects_overflow():
    g = _geom(0, 16)
    assert pad_geometry(g, 16) is g
    with pytest.raises(ValueError):
        pad_geometry(g, 12)


def test_pad_weight_survives_float32():
    # the PR-3 lesson: the pad weight must stay a *normal* float32 (XLA
    # CPU flushes subnormals to zero, which re-enters log/clamp paths as
    # full-mass garbage)
    assert np.float32(PAD_WEIGHT) > np.finfo(np.float32).tiny


def test_padded_solve_matches_unpadded_values():
    prob = _problem(0, 14)
    padded = pad_problem(prob, 16, 16)
    out_ref = repro.solve(prob, CLEAN)
    out_pad = repro.solve(padded, CLEAN, validate=False)
    np.testing.assert_allclose(float(out_pad.value), float(out_ref.value),
                               rtol=1e-4)
    T_pad = np.asarray(out_pad.coupling_dense(16, 16))
    T_ref = np.asarray(out_ref.coupling_dense(14, 14))
    # the ~1e-30 pad mass perturbs float32 iterates; ten outer iterations
    # amplify that to ~1e-4 in individual coupling entries (entries are
    # O(1/n) ~ 0.07 here, so this is still <1% of entry scale)
    np.testing.assert_allclose(T_pad[:14, :14], T_ref, atol=5e-4)
    # padded rows carry ~PAD_WEIGHT of mass, invisible at float32
    assert float(T_pad[14:, :].sum()) < 1e-6


# ---------------------------------------------------------------------------
# batch signatures
# ---------------------------------------------------------------------------

def test_batch_signature_groups_same_shape_and_config():
    a = (pad_problem(_problem(0, 14), 16, 16), CLEAN, None)
    b = (pad_problem(_problem(9, 12), 16, 16), CLEAN, None)
    assert batch_signature(a) == batch_signature(b)


def test_batch_signature_separates_shapes_and_solver_knobs():
    p16 = (pad_problem(_problem(0, 14), 16, 16), CLEAN, None)
    p24 = (pad_problem(_problem(0, 14), 24, 24), CLEAN, None)
    assert batch_signature(p16) != batch_signature(p24)
    other = dataclasses.replace(CLEAN, outer_iters=11)
    assert batch_signature(p16) != batch_signature(
        (p16[0], other, None))


# ---------------------------------------------------------------------------
# Geometry.content_hash
# ---------------------------------------------------------------------------

def test_content_hash_construction_path_invariant():
    rng = np.random.default_rng(0)
    C = np.asarray(rng.random((8, 8)), np.float32)
    w = np.full(8, 1 / 8, np.float32)
    h_np = Geometry(C, w).content_hash()
    h_jnp = Geometry(jnp.asarray(C), jnp.asarray(w)).content_hash()
    h_F = Geometry(np.asfortranarray(C), w).content_hash()
    assert h_np == h_jnp == h_F


def test_content_hash_from_points_matches_explicit_ctor():
    rng = np.random.default_rng(1)
    p = np.asarray(rng.random((9, 3)), np.float32)
    w = np.full(9, 1 / 9, np.float32)
    assert (Geometry.from_points(p, w).content_hash()
            == Geometry(None, w, points=p).content_hash())


def test_content_hash_sensitivity():
    rng = np.random.default_rng(2)
    C = np.asarray(rng.random((8, 8)), np.float32)
    w = np.full(8, 1 / 8, np.float32)
    base = Geometry(C, w).content_hash()
    assert Geometry(C.astype(np.float64), w).content_hash() != base
    w2 = w.copy()
    w2[0] += np.float32(1e-6)
    assert Geometry(C, w2, validate=False).content_hash() != base
    C2 = C.copy()
    C2[3, 4] += np.float32(1e-6)
    assert Geometry(C2, w).content_hash() != base


def test_content_hash_point_cloud_never_materializes_cost(monkeypatch):
    rng = np.random.default_rng(3)
    p = np.asarray(rng.random((50, 3)), np.float32)
    g = Geometry.from_points(p, np.full(50, 1 / 50, np.float32))

    def boom(self):
        raise AssertionError("content_hash materialized the n x n cost")

    monkeypatch.setattr(Geometry, "cost_matrix", property(boom))
    assert isinstance(g.content_hash(), str)


def test_content_hash_memoized_and_rejects_tracers():
    g = _geom(0, 8)
    assert g.content_hash() is g.content_hash()

    def inside(c):
        Geometry(c, jnp.ones(8) / 8, validate=False).content_hash()
        return c

    with pytest.raises(ValueError, match="concrete"):
        jax.jit(inside)(g.cost)


# ---------------------------------------------------------------------------
# GeometryCache
# ---------------------------------------------------------------------------

def test_cache_counters_and_artifact_reuse():
    cache = GeometryCache(8)
    g = _geom(0, 14)
    a1 = cache.padded(g, 16)
    a2 = cache.padded(g, 16)
    assert a1 is a2
    assert (cache.hits, cache.misses) == (1, 1)
    # same content, different object -> still a hit
    g2 = Geometry(jnp.asarray(np.asarray(g.cost)), g.weights)
    assert cache.padded(g2, 16) is a1
    assert cache.hits == 2


def test_cache_lru_eviction():
    cache = GeometryCache(2)
    gs = [_geom(s, 12) for s in range(3)]
    for g in gs:
        cache.padded(g, 16)
    assert len(cache) == 2 and cache.evictions == 1
    cache.padded(gs[0], 16)          # was evicted -> miss again
    assert cache.misses == 4
    stats = cache.stats()
    assert stats["entries"] == 2 and stats["hit_rate"] == 0.0


def test_cache_lowrank_factors_and_anchors():
    rng = np.random.default_rng(4)
    pts = np.asarray(rng.random((12, 2)), np.float32)
    g = Geometry.from_points(jnp.asarray(pts),
                             jnp.full(12, 1 / 12, jnp.float32))
    cache = GeometryCache(8)
    fac = cache.lowrank_factors(g)
    np.testing.assert_allclose(np.asarray(fac.todense()),
                               np.asarray(g.cost_matrix), atol=1e-5)
    idx1 = cache.anchors(g, 4)
    idx2 = GeometryCache(8).anchors(g, 4)    # fresh cache, same geometry
    assert _bits(idx1, idx2)                 # pure function of the geometry
    with pytest.raises(ValueError, match="point-cloud"):
        cache.lowrank_factors(_geom(0, 8))


def test_cache_warm_populates_all_artifacts():
    rng = np.random.default_rng(5)
    pts = np.asarray(rng.random((10, 2)), np.float32)
    g = Geometry.from_points(jnp.asarray(pts),
                             jnp.full(10, 1 / 10, jnp.float32))
    cache = GeometryCache(8)
    cache.warm(g, buckets=(16, 24), k=3)
    assert len(cache) == 4 and cache.hits == 0
    cache.warm(g, buckets=(16, 24), k=3)     # all hits now
    assert cache.hits == 4


# ---------------------------------------------------------------------------
# percentiles
# ---------------------------------------------------------------------------

def test_percentiles_basic_and_empty():
    p = percentiles(list(range(1, 101)))
    assert p["p50"] == pytest.approx(50.5)
    assert p["p50"] <= p["p95"] <= p["p99"] <= 100
    empty = percentiles([])
    assert all(np.isnan(v) for v in empty.values())


# ---------------------------------------------------------------------------
# server end-to-end
# ---------------------------------------------------------------------------

def test_server_results_match_eager_solve():
    srv = GWServer(ServeConfig(max_batch=4, max_wait_s=60.0,
                               on_failure="none"))
    probs = [_problem(s, 12 + s) for s in range(3)]
    rids = [srv.submit(p, CLEAN) for p in probs]
    for res, prob in zip(srv.results(rids), probs):
        ref = repro.solve(prob, CLEAN)
        np.testing.assert_allclose(res.value, float(ref.value), rtol=1e-4)
        m, n = prob.shape
        np.testing.assert_allclose(np.asarray(res.coupling_dense()),
                                   np.asarray(ref.coupling_dense(m, n)),
                                   atol=1e-5)
        assert res.shape == (m, n) and not res.failed


def test_server_lifecycle_poll_and_stats():
    srv = GWServer(ServeConfig(max_batch=8, max_wait_s=60.0,
                               on_failure="none"))
    rid = srv.submit(_problem(0, 14), CLEAN)
    assert srv.poll(rid) == "queued"
    srv.flush()
    assert srv.poll(rid) in ("running", "done")
    res = srv.result(rid)
    assert srv.poll(rid) == "done"
    assert res is srv.result(rid)            # idempotent
    stats = srv.stats()
    assert stats["n_completed"] == 1 and stats["n_batches"] == 1
    assert stats["mean_batch_lanes"] >= MIN_LANES   # filler lane added
    assert np.isfinite(stats["latency_p99_ms"])
    with pytest.raises(KeyError):
        srv.result(999)


def test_server_eager_key_validation():
    srv = GWServer()
    with pytest.raises(ValueError, match="PRNG key"):
        srv.submit(_problem(0, 14), "spar_gw")


def test_serve_config_validation():
    with pytest.raises(ValueError):
        ServeConfig(on_failure="retry")
    with pytest.raises(ValueError):
        ServeConfig(max_batch=0)


def test_server_multi_bucket_routing():
    srv = GWServer(ServeConfig(max_batch=8, max_wait_s=60.0,
                               on_failure="none"))
    rids = [srv.submit(_problem(s, n), CLEAN)
            for s, n in enumerate((12, 20, 14, 28))]
    res = srv.results(rids)
    assert [r.padded_shape for r in res] == [(16, 16), (24, 24), (16, 16),
                                             (32, 32)]
    # 3 buckets: (16,16) holds two requests, the others one + filler
    assert srv.stats()["n_batches"] == 3


# ---------------------------------------------------------------------------
# lane isolation: the serving-boundary acceptance criterion
# ---------------------------------------------------------------------------

def test_poisoned_lane_isolated_and_mates_bitwise_solo():
    """One FaultSpec-poisoned request in a bucket must (a) come back
    DIVERGED itself and (b) leave every bucket-mate bitwise identical to
    the mate's solo (eager, unbatched) solve."""
    seeds = [0, 1, 2, 5]
    probs = [_problem(s, 14) for s in seeds]
    solvers = [CLEAN, POISONED, CLEAN, CLEAN]
    srv = GWServer(ServeConfig(max_batch=4, max_wait_s=60.0,
                               on_failure="none"))
    rids = [srv.submit(p, s) for p, s in zip(probs, solvers)]
    res = srv.results(rids)

    assert res[1].status_name == "DIVERGED" and res[1].failed
    assert srv.stats()["n_batches"] == 1     # one bucket held all four

    # solo references: one fresh server, one request per batch (submit ->
    # result immediately, so nothing shares a bucket)
    solo_srv = GWServer(ServeConfig(max_batch=4, max_wait_s=60.0,
                                    on_failure="none"))
    for i in (0, 2, 3):
        solo = solo_srv.result(solo_srv.submit(probs[i], CLEAN))
        assert not res[i].failed
        assert _bits(res[i].output.value, solo.output.value)
        assert _bits(res[i].output.coupling_dense(16, 16),
                     solo.output.coupling_dense(16, 16))


def test_filler_lanes_do_not_change_request_bits():
    # lane 1 holding a disarmed filler replica vs lane 1 holding a real
    # different request: lane 0's bits must not change (even when lane 0
    # itself is the poisoned, diverging one)
    prob = _problem(3, 13)
    srv_solo = GWServer(ServeConfig(max_batch=8, max_wait_s=60.0,
                                    on_failure="none"))
    solo = srv_solo.result(srv_solo.submit(prob, POISONED))
    srv_pair = GWServer(ServeConfig(max_batch=2, max_wait_s=60.0,
                                    on_failure="none"))
    rid0 = srv_pair.submit(prob, POISONED)
    rid1 = srv_pair.submit(_problem(8, 15), CLEAN)
    paired = srv_pair.results([rid0, rid1])[0]
    assert solo.status_name == paired.status_name == "DIVERGED"
    assert _bits(solo.output.value, paired.output.value)
    assert _bits(solo.output.coupling, paired.output.coupling)


# ---------------------------------------------------------------------------
# per-request fallback
# ---------------------------------------------------------------------------

def test_poisoned_request_falls_back_mates_untouched():
    persistent = dataclasses.replace(
        BASE, max_rescues=0,
        fault=FaultSpec(at_iter=1, kind="nan", persistent=True))
    probs = [_problem(s, 14) for s in (0, 1, 2, 5)]
    solvers = [CLEAN, persistent, CLEAN, CLEAN]
    srv = GWServer(ServeConfig(max_batch=4, max_wait_s=60.0,
                               on_failure="fallback"))
    rids = [srv.submit(p, s, key=jax.random.PRNGKey(100 + i))
            for i, (p, s) in enumerate(zip(probs, solvers))]
    res = srv.results(rids)

    # the poisoned request recovered through the ladder, at its own shape
    assert res[1].failed and res[1].fell_back
    assert int(np.asarray(res[1].status.code)) < STALLED
    assert np.isfinite(res[1].value)
    assert res[1].coupling_dense().shape == (14, 14)
    assert srv.stats()["n_fallbacks"] == 1

    # mates stayed on the batched path, bitwise equal to solo
    for i in (0, 2, 3):
        assert not res[i].fell_back
        padded = pad_problem(probs[i], 16, 16)
        ref = CLEAN.run(padded, jax.random.PRNGKey(100 + i))
        assert _bits(res[i].output.value, ref.value)


def test_keyless_dense_fallback_returns_batched_output():
    # with no PRNG key the ladder has no key-free rungs besides the
    # primary -> fallback cannot recover; the batched DIVERGED output is
    # returned honestly (failed=True, fell_back=False)
    persistent = dataclasses.replace(
        BASE, max_rescues=0,
        fault=FaultSpec(at_iter=1, kind="nan", persistent=True))
    srv = GWServer(ServeConfig(max_batch=2, max_wait_s=60.0,
                               on_failure="fallback"))
    res = srv.result(srv.submit(_problem(0, 14), persistent))
    assert res.failed and not res.fell_back
    assert res.status_name == "DIVERGED"


# ---------------------------------------------------------------------------
# persistent compilation cache
# ---------------------------------------------------------------------------

def test_compilation_cache_skips_recompile_in_fresh_process(tmp_path):
    """Two identical server processes sharing a cache dir: the first
    populates it, the second (fresh process, cold in-memory caches)
    deserializes every executable — no new cache entries."""
    import os
    import pathlib
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
        import sys
        import jax, jax.numpy as jnp
        import repro
        from repro.serve import GWServer, ServeConfig

        server = GWServer(ServeConfig(compilation_cache_dir=sys.argv[1],
                                      max_batch=1))
        n = 20
        x = jax.random.normal(jax.random.PRNGKey(0), (n, 2))
        y = jax.random.normal(jax.random.PRNGKey(1), (n, 2))
        a = jnp.ones(n) / n
        p = repro.QuadraticProblem(repro.Geometry.from_points(x, a),
                                   repro.Geometry.from_points(y, a))
        solver = repro.DenseGWSolver(outer_iters=5, inner_iters=10)
        res = server.result(server.submit(p, solver))
        assert not res.failed, res.status_name
        print("VALUE", float(res.value))
    """)
    root = pathlib.Path(__file__).resolve().parent.parent
    env = {**os.environ, "PYTHONPATH": str(root / "src"),
           "PYTHONHASHSEED": "0"}

    def run_once():
        proc = subprocess.run(
            [sys.executable, "-c", code, str(tmp_path)],
            capture_output=True, text=True, env=env, cwd=root, timeout=420)
        assert proc.returncode == 0, proc.stderr[-2000:]
        value = [ln for ln in proc.stdout.splitlines()
                 if ln.startswith("VALUE")][0]
        entries = sorted(p.name for p in tmp_path.rglob("*") if p.is_file())
        return value, entries

    value1, entries1 = run_once()
    assert entries1, "first run persisted no executables"
    value2, entries2 = run_once()
    assert value2 == value1
    assert entries2 == entries1, (
        f"second process recompiled: {set(entries2) - set(entries1)}")
