"""Unified telemetry layer: convergence traces (in-jit, vmap-safe),
lifecycle spans, the process metrics registry, and the exporters."""
import dataclasses
import json
import threading
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import obs
from repro.health import CONVERGED, DIVERGED, MAXITER, FaultSpec, health_loop
from repro.obs.registry import MetricsRegistry
from repro.serve import GWServer, ServeConfig

KEY = jax.random.PRNGKey(0)
N = 24


def _problem(seed=0, n=N, loss="l2"):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))

    def cloud(key, scale):
        x = jax.random.normal(key, (n, 2)) * scale
        return jnp.sqrt(jnp.sum((x[:, None] - x[None, :]) ** 2, -1))

    a = jnp.ones(n) / n
    return repro.QuadraticProblem(repro.Geometry(cloud(kx, 1.0), a),
                                  repro.Geometry(cloud(ky, 1.2), a),
                                  loss="l2")


# ---------------------------------------------------------------------------
# Convergence traces: health_loop unit behavior
# ---------------------------------------------------------------------------

def test_trace_off_is_bitwise_identical():
    """trace=False must be the exact pre-obs loop: same bits, no trace."""
    step = lambda T: 0.9 * T + 0.1           # noqa: E731
    err = lambda T: jnp.sum(jnp.abs(T - 1))  # noqa: E731
    plain = health_loop(step, err, jnp.zeros(4), 30, 1e-6)
    traced = health_loop(step, err, jnp.zeros(4), 30, 1e-6, trace=True)
    assert plain.trace is None
    assert traced.trace is not None
    np.testing.assert_array_equal(np.asarray(plain.iterate),
                                  np.asarray(traced.iterate))
    np.testing.assert_array_equal(np.asarray(plain.errors),
                                  np.asarray(traced.errors), strict=True)
    assert int(plain.n_iters) == int(traced.n_iters)
    assert int(plain.status.code) == int(traced.status.code)


def test_trace_length_equals_n_iters_converged():
    step = lambda T: 0.5 * T + 0.5           # noqa: E731 — fast contraction
    err = lambda T: jnp.sum(jnp.abs(T - 1))  # noqa: E731
    res = health_loop(step, err, jnp.zeros(4), 100, 1e-6, trace=True)
    assert int(res.status.code) == CONVERGED
    n = int(res.n_iters)
    assert 0 < n < 100
    assert obs.n_valid(res.trace) == n
    # recorded prefix is finite, the rest stays NaN fill
    assert np.all(np.isfinite(np.asarray(res.trace.err)[:n]))
    assert np.all(np.isnan(np.asarray(res.trace.err)[n:]))


def test_trace_length_equals_n_iters_maxiter():
    step = lambda T: T + 1.0                 # noqa: E731 — never settles
    err = lambda T: jnp.float32(0.0)         # noqa: E731
    res = health_loop(step, err, jnp.zeros(2), 7, 1e-9, trace=True)
    assert int(res.status.code) == MAXITER
    assert int(res.n_iters) == 7
    assert obs.n_valid(res.trace) == 7


def test_trace_records_rescue_forensics():
    """A rescue iteration keeps its record: the bad mass, the scale that
    failed, rescued=1; the next attempt runs at the escalated scale."""
    step = lambda T: 0.9 * T + 0.1           # noqa: E731
    err = lambda T: jnp.sum(jnp.abs(T - 1))  # noqa: E731
    res = health_loop(step, err, jnp.zeros(4), 10, 0.0, max_rescues=2,
                      fault=FaultSpec(at_iter=2, kind="nan"), trace=True)
    tr = res.trace
    rescued = np.asarray(tr.rescued)
    assert rescued[2] == 1.0 and np.nansum(rescued) == 1.0
    assert not np.isfinite(np.asarray(tr.mass)[2])   # the poisoned attempt
    scale = np.asarray(tr.scale)
    assert scale[2] == 1.0                  # scale in effect when it failed
    assert scale[3] == 2.0                  # escalated after the rescue
    # err/objective/delta describe accepted steps only: NaN at the rescue
    assert np.isnan(np.asarray(tr.err)[2])
    assert int(res.status.n_rescues) == 1


def test_trace_objective_column():
    step = lambda T: 0.5 * T + 0.5           # noqa: E731
    err = lambda T: jnp.sum(jnp.abs(T - 1))  # noqa: E731
    obj = lambda T: jnp.sum(T)               # noqa: E731
    with_obj = health_loop(step, err, jnp.zeros(4), 50, 1e-6, trace=True,
                           obj_fn=obj)
    n = int(with_obj.n_iters)
    assert np.all(np.isfinite(np.asarray(with_obj.trace.objective)[:n]))
    without = health_loop(step, err, jnp.zeros(4), 50, 1e-6, trace=True)
    assert np.all(np.isnan(np.asarray(without.trace.objective)))
    # trace_to_dict maps the NaN objective column to None, not NaN
    doc = obs.trace_to_dict(without.trace)
    assert doc["objective"] == [None] * doc["n_iters"]
    json.dumps(doc)


def test_trace_vmap_lane_isolation():
    """One poisoned lane dies with its own forensic trace; its healthy
    peer's buffers are untouched (the health layer's masking contract)."""
    def run(at_iter):
        step = lambda T: 0.9 * T + 0.1           # noqa: E731
        err = lambda T: jnp.sum(jnp.abs(T - 1))  # noqa: E731
        res = health_loop(step, err, jnp.zeros(4), 10, 0.0,
                          fault=FaultSpec(at_iter=at_iter, kind="nan"),
                          trace=True)
        return res.trace, res.status.code, res.n_iters

    traces, codes, n_iters = jax.jit(jax.vmap(run))(
        jnp.array([-1, 3], jnp.int32))
    assert traces.err.shape == (2, 10)
    assert int(codes[0]) == MAXITER and int(codes[1]) == DIVERGED
    # healthy lane: full-length, everywhere-finite record
    assert np.all(np.isfinite(np.asarray(traces.mass)[0]))
    assert np.nansum(np.asarray(traces.rescued)[0]) == 0.0
    # poisoned lane: dead at iter 3 — 4 consumed iterations, bad mass at 3
    assert int(n_iters[1]) == 4
    lane1 = jax.tree.map(lambda x: x[1], traces)
    assert obs.n_valid(lane1) == 4
    assert not np.isfinite(np.asarray(traces.mass)[1, 3])
    assert np.all(np.isnan(np.asarray(traces.err)[1, 4:]))


# ---------------------------------------------------------------------------
# Convergence traces: through the solver stack
# ---------------------------------------------------------------------------

def test_solver_trace_off_bitwise_identical():
    problem = _problem()
    base = repro.DenseGWSolver(outer_iters=8, tol=0.0, inner_tol=1e-8)
    out_off = repro.solve(problem, base, validate=False)
    out_on = repro.solve(problem, dataclasses.replace(base, trace=True),
                         validate=False)
    assert out_off.trace is None
    np.testing.assert_array_equal(np.asarray(out_off.coupling_dense(N, N)),
                                  np.asarray(out_on.coupling_dense(N, N)))
    assert float(out_off.value) == float(out_on.value)


@pytest.mark.parametrize("name,kw", [
    ("dense_gw", dict(outer_iters=8, inner_tol=1e-8)),
    ("spar_gw", dict(s=8 * N, outer_iters=8, inner_tol=1e-8)),
    ("grid_gw", dict(s_r=12, s_c=12, outer_iters=8, inner_tol=1e-8)),
    ("lowrank_gw", dict(outer_iters=20)),
])
def test_every_family_produces_a_trace(name, kw):
    problem = _problem()
    solver = dataclasses.replace(
        repro.get_solver(name).default_config(N), trace=True, **kw)
    key = KEY if getattr(type(solver), "requires_key", False) else None
    out = repro.solve(problem, solver, key=key, validate=False)
    assert out.trace is not None
    n = int(out.n_iters)
    assert obs.n_valid(out.trace) == n > 0
    # every family supplies an obj_fn: the objective column is populated
    assert np.all(np.isfinite(np.asarray(out.trace.objective)[:n]))
    doc = obs.trace_to_dict(out.trace, n)
    assert doc["n_iters"] == n and len(doc["err"]) == n
    json.dumps(doc)


def test_solver_trace_under_jit_vmap():
    problem = _problem()
    solver = repro.SparGWSolver(s=8 * N, outer_iters=6, tol=0.0,
                                inner_tol=1e-8, trace=True)
    keys = jax.random.split(KEY, 2)
    out = jax.jit(jax.vmap(lambda k: solver.run(problem, k)))(keys)
    assert out.trace.err.shape == (2, 6)
    assert np.all(np.isfinite(np.asarray(out.trace.err)))
    # distinct supports -> distinct per-lane trajectories
    assert not np.array_equal(np.asarray(out.trace.err)[0],
                              np.asarray(out.trace.err)[1])


# ---------------------------------------------------------------------------
# Spans
# ---------------------------------------------------------------------------

def test_span_nesting_and_attrs():
    obs.clear_spans()
    with obs.span("outer", tag="a"):
        with obs.span("inner") as sp:
            sp["extra"] = 42
    recs = obs.spans()
    by_name = {r["name"]: r for r in recs}
    assert by_name["outer"]["depth"] == 0 and by_name["outer"]["tag"] == "a"
    assert by_name["inner"]["depth"] == 1
    assert by_name["inner"]["parent"] == "outer"
    assert by_name["inner"]["extra"] == 42
    # start order: outer first despite completing last
    assert [r["name"] for r in recs] == ["outer", "inner"]
    bd = obs.span_breakdown(recs)
    assert bd["outer"]["count"] == 1
    assert bd["outer"]["total_s"] >= by_name["inner"]["duration_s"]


def test_span_stack_is_thread_local():
    obs.clear_spans()
    ready = threading.Barrier(2)

    def work(tag):
        ready.wait()
        with obs.span("t", tag=tag):
            pass

    threads = [threading.Thread(target=work, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    recs = [r for r in obs.spans() if r["name"] == "t"]
    assert len(recs) == 2
    # neither thread saw the other's span as its parent
    assert all(r["depth"] == 0 and r["parent"] is None for r in recs)


def test_solve_emits_lifecycle_spans():
    obs.clear_spans()
    problem = _problem(seed=3)
    repro.solve(problem,
                repro.DenseGWSolver(tol=1e-6, inner_tol=1e-8,
                                    outer_iters=10),
                on_failure="raise")
    names = [r["name"] for r in obs.spans()]
    assert "solve" in names and "solve.dispatch" in names
    disp = [r for r in obs.spans() if r["name"] == "solve.dispatch"]
    assert all(r["parent"] == "solve" for r in disp)
    assert all("compiled" in r for r in disp)


# ---------------------------------------------------------------------------
# Registry + exporters
# ---------------------------------------------------------------------------

def test_registry_primitives():
    reg = MetricsRegistry()
    c = reg.counter("r_total", "help", solver="dense")
    c.inc()
    c.inc(2)
    assert reg.counter("r_total", solver="dense") is c   # get-or-create
    assert c.value == 3.0
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("r_gauge")
    g.set(1.5)
    g.inc(0.5)
    assert g.value == 2.0
    with pytest.raises(ValueError):
        reg.gauge("r_total")        # kind mismatch
    with pytest.raises(ValueError):
        reg.counter("bad name")


def test_histogram_and_snapshot_roundtrip():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    assert h.count == 3 and h.bucket_counts == [1, 2]    # cumulative
    assert h.percentiles((50,))["p50"] == pytest.approx(0.5)
    reg.gauge("g").set(float("nan"))          # must not break JSON
    snap = json.loads(json.dumps(reg.snapshot()))
    row = snap["metrics"]["lat_seconds"]["series"][0]
    assert row["count"] == 3 and row["n_seen"] == 3
    assert snap["metrics"]["g"]["series"][0]["value"] is None


def test_reservoir_bounded_exact_then_sampled():
    r = obs.Reservoir(cap=16, seed=1)
    for i in range(16):
        r.add(float(i))
    assert sorted(r) == [float(i) for i in range(16)]    # exact below cap
    for i in range(1000):
        r.add(float(i))
    assert len(r) == 16 and r.n_seen == 1016             # bounded forever


def test_serve_metrics_latency_store_is_bounded():
    from repro.serve.metrics import ServeMetrics, percentiles
    m = ServeMetrics(sample_cap=8)
    for _ in range(50):
        t = m.record_submit()
        m.record_result(t, t, failed=False, fell_back=False)
    assert len(m.latencies_s) == 8 and m.latencies_s.n_seen == 50
    assert m.summary()["n_completed"] == 50
    # the PR-7 shim: serve.metrics.percentiles is the obs definition
    assert percentiles is obs.percentiles


def test_percentiles_empty_is_nan():
    p = obs.percentiles([])
    assert all(np.isnan(v) for v in p.values())


def test_prometheus_text_validates():
    reg = MetricsRegistry()
    reg.counter("x_total", "things", kind='we"ird\n').inc(3)
    reg.histogram("x_seconds", "latency", buckets=(0.1, 1.0)).observe(0.2)
    text = reg.prometheus_text()
    n = obs.validate_exposition(text)
    # 1 counter sample + (2 buckets + +Inf + sum + count)
    assert n == 6
    assert "# TYPE x_seconds histogram" in text
    assert 'x_seconds_bucket{le="+Inf"} 1' in text
    with pytest.raises(ValueError):
        obs.validate_exposition("no trailing newline")
    with pytest.raises(ValueError):
        obs.validate_exposition("}bad{ 1\n")


def test_write_jsonl(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c_total").inc()
    path = tmp_path / "metrics.jsonl"
    reg.write_jsonl(path, extra={"run": "a"})
    reg.write_jsonl(path)
    lines = path.read_text().splitlines()
    assert len(lines) == 2
    assert json.loads(lines[0])["run"] == "a"
    assert "c_total" in json.loads(lines[1])["metrics"]


def test_http_exporter():
    reg = MetricsRegistry()
    reg.counter("http_test_total").inc()
    server = obs.serve_metrics_http(0, reg=reg)      # ephemeral port
    host, port = server.server_address[:2]
    try:
        with urllib.request.urlopen(
                f"http://{host}:{port}/metrics", timeout=5) as resp:
            assert resp.status == 200
            assert resp.headers["Content-Type"].startswith("text/plain")
            body = resp.read().decode()
        assert "http_test_total 1.0" in body
        obs.validate_exposition(body)
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://{host}:{port}/nope", timeout=5)
    finally:
        server.shutdown()


# ---------------------------------------------------------------------------
# report(): one JSON document
# ---------------------------------------------------------------------------

def test_report_ties_everything_together():
    obs.clear_spans()
    problem = _problem(seed=5)
    solver = repro.DenseGWSolver(outer_iters=8, tol=0.0, inner_tol=1e-8,
                                 trace=True)
    out = repro.solve(problem, solver, on_failure="raise")
    doc = obs.report(out, solver="dense_gw")
    assert set(doc) == {"solve", "spans", "breakdown", "metrics"}
    assert doc["solve"]["solver"] == "dense_gw"
    assert doc["solve"]["n_iters"] == 8
    assert len(doc["solve"]["trace"]["err"]) == 8
    assert doc["breakdown"]["by_name"]["solve.dispatch"]["count"] >= 1
    assert doc["breakdown"]["compile_s"] + doc["breakdown"]["dispatch_s"] > 0
    assert "repro_solves_total" in doc["metrics"]["metrics"]
    json.dumps(doc)                      # the whole point: one JSON doc
    # argument-less report() describes the solve note_solve() stashed
    assert obs.report()["solve"]["n_iters"] == 8


# ---------------------------------------------------------------------------
# GWServer: flusher thread + Prometheus surface
# ---------------------------------------------------------------------------

def test_flusher_thread_fires_on_wall_clock():
    """A lone queued request must dispatch within ~max_wait_s with no
    further server calls — proven by the timer-tagged dispatch span."""
    obs.clear_spans()
    server = GWServer(ServeConfig(max_batch=8, max_wait_s=0.05,
                                  on_failure="none"))
    try:
        problem = _problem(seed=7, n=12)
        solver = repro.DenseGWSolver(outer_iters=4, inner_tol=1e-6)
        rid = server.submit(problem, solver)
        import time
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < 5.0:
            timer_spans = [r for r in obs.spans()
                           if r["name"] == "serve.dispatch"
                           and r.get("source") == "timer"]
            if timer_spans:
                break
            time.sleep(0.02)
        assert timer_spans, "flusher thread never dispatched the bucket"
        res = server.result(rid)
        assert res.status_name in ("CONVERGED", "MAXITER")
    finally:
        server.close()


def test_flush_thread_off_is_cooperative():
    server = GWServer(ServeConfig(max_batch=8, max_wait_s=60.0,
                                  flush_thread=False, on_failure="none"))
    try:
        assert server._flusher is None
        rid = server.submit(_problem(seed=8, n=12),
                            repro.DenseGWSolver(outer_iters=4,
                                                inner_tol=1e-6))
        assert server.poll(rid) == "queued"      # nobody flushes for us
        res = server.result(rid)                 # result() forces the flush
        assert np.isfinite(res.value)
    finally:
        server.close()


def test_server_metrics_text_is_valid_exposition():
    server = GWServer(ServeConfig(max_batch=2, max_wait_s=60.0,
                                  on_failure="none"))
    try:
        solver = repro.DenseGWSolver(outer_iters=4, inner_tol=1e-6)
        rids = [server.submit(_problem(seed=9 + i, n=12), solver)
                for i in range(2)]
        server.results(rids)
        text = server.metrics_text()
        assert obs.validate_exposition(text) > 0
        assert "repro_serve_requests_total" in text
        assert "repro_serve_latency_seconds_bucket" in text
    finally:
        server.close()
