"""Fault tolerance at the system level: bit-exact resume after restart and
elastic restore onto a different mesh topology."""
import shutil

import numpy as np
import pytest


def test_resume_is_bit_exact(tmp_path, multi_device_runner):
    """Train 8 steps straight vs 4 steps + checkpoint + restart + 4 steps."""
    out = multi_device_runner(f"""
import jax, numpy as np
from repro.configs import base as cb
from repro.launch.train import train
cfg = cb.get_reduced("smollm_135m")
# run A: straight through
_, _, hist_a = train(cfg, 8, 4, 32, ckpt_dir=None, log_every=0)
# run B: 4 steps + ckpt (same 8-step lr schedule), restart, finish to 8
import shutil; shutil.rmtree("{tmp_path}/ck", ignore_errors=True)
train(cfg, 4, 4, 32, ckpt_dir="{tmp_path}/ck", ckpt_every=4, log_every=0,
      schedule_total=8)
_, _, hist_b = train(cfg, 8, 4, 32, ckpt_dir="{tmp_path}/ck", ckpt_every=4, log_every=0)
la = [h["loss"] for h in hist_a[4:]]
lb = [h["loss"] for h in hist_b]
assert np.allclose(la, lb, rtol=1e-5), (la, lb)
print("ok")
""", n_devices=1)
    assert "ok" in out


def test_elastic_restore_other_mesh(tmp_path, multi_device_runner):
    """Save from a (2,2) mesh, restore onto (4,1) and (1,1) — the
    checkpoint format is sharding-agnostic."""
    multi_device_runner(f"""
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager
from repro.configs import base as cb
from repro.distrib import sharding as shd
from repro.models.model_zoo import Model

cfg = cb.get_reduced("llama3_8b")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

mesh_a = jax.make_mesh((2,2), ("data","model"))
sh_a = shd.param_shardings(model.param_axes(), model.abstract_params(), mesh_a)
params_a = jax.device_put(params, sh_a)
mgr = CheckpointManager("{tmp_path}/elastic", keep=2)
mgr.save(1, params_a)

mesh_b = jax.make_mesh((4,1), ("data","model"))
sh_b = shd.param_shardings(model.param_axes(), model.abstract_params(), mesh_b)
restored, _ = mgr.restore(1, params, sh_b)
for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
# and a different device count entirely (single device)
mesh_c = jax.make_mesh((1,1), ("data","model"))
sh_c = shd.param_shardings(model.param_axes(), model.abstract_params(), mesh_c)
restored_c, _ = mgr.restore(1, params, sh_c)
for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(restored_c)):
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
print("ok")
""")


def test_straggler_watchdog_detects():
    from repro.launch.train import StragglerWatchdog
    wd = StragglerWatchdog(factor=2.0)
    for _ in range(5):
        wd.observe(0, 0.1)
    assert wd.observe(6, 0.5)
    assert not wd.observe(7, 0.11)
    assert len(wd.events) == 1
