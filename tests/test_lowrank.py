"""Low-rank GW subsystem: cost factorization exactness, LR-Dykstra
feasibility, the registered lowrank_gw solver (accuracy vs converged
dense_gw across ranks, coupling feasibility, jit+vmap composition,
degenerate marginals), the LowRankCoupling container, point-cloud
Geometry support, and multiscale nesting in both directions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import (
    Geometry,
    LowRankCoupling,
    LowRankGWSolver,
    QuadraticProblem,
    QuantizedGWSolver,
    solve,
)
from repro.core.gw import gw_objective
from repro.lowrank import (
    khatri_rao_square,
    lr_dykstra,
    sketch_factors,
    sq_euclidean_factors,
)

KEY = jax.random.PRNGKey(0)

# heavy-projection config for feasibility-critical assertions
TIGHT = dict(inner_iters=2000, inner_tol=1e-9)
DENSE_REF = repro.DenseGWSolver(epsilon=1e-2, outer_iters=80,
                                inner_iters=2000, tol=1e-6, inner_tol=1e-8)


def _uniform(n):
    return jnp.ones(n) / n


def _cloud_problem(seed=0, n=150, d=2, scale_y=1.2):
    """Independent gaussian point clouds as point-cloud geometries."""
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (n, d))
    y = jax.random.normal(ky, (n, d)) * scale_y
    return QuadraticProblem(Geometry.from_points(x, _uniform(n)),
                            Geometry.from_points(y, _uniform(n)))


def _atoms_problem(seed=1, n=150, k=4):
    """n points on k distinct locations, second space a 1.5× dilation.

    The optimal coupling is the cluster-identity block coupling — exactly
    rank k — so every rank ≥ k must recover the same value, and that
    value is computable in closed form (`_atoms_optimum`).
    """
    centers = jax.random.normal(jax.random.PRNGKey(seed), (k, 2)) * 3.0
    assign = jnp.arange(n) % k
    x = centers[assign]
    y = 1.5 * x
    prob = QuadraticProblem(Geometry.from_points(x, _uniform(n)),
                            Geometry.from_points(y, _uniform(n)))
    return prob, assign


def _atoms_optimum(prob, assign):
    n = assign.shape[0]
    B = (assign[:, None] == assign[None, :]).astype(jnp.float32)
    T_blk = B / B.sum(axis=1, keepdims=True) / n
    return float(gw_objective(prob.geom_x.cost_matrix,
                              prob.geom_y.cost_matrix, T_blk, "l2"))


def _densified(prob):
    return QuadraticProblem(
        Geometry(prob.geom_x.cost_matrix, prob.geom_x.weights),
        Geometry(prob.geom_y.cost_matrix, prob.geom_y.weights))


# ---------------------------------------------------------------------------
# cost factorization
# ---------------------------------------------------------------------------

def test_sq_euclidean_factors_exact_rank_d_plus_2():
    """||x_i - x_j||² factors at rank d+2 with ~fp32-roundoff error."""
    n, d = 120, 3
    x = jax.random.normal(KEY, (n, d))
    f = sq_euclidean_factors(x)
    assert f.u.shape == (n, d + 2) and f.v.shape == (n, d + 2)
    D = jnp.sum((x[:, None] - x[None, :]) ** 2, -1)
    err = float(jnp.abs(f.todense() - D).max())
    assert err <= 1e-5 * float(D.max())
    # matvec contract agrees with the dense product
    v = jax.random.normal(jax.random.PRNGKey(1), (n,))
    np.testing.assert_allclose(np.asarray(f.apply(v)), np.asarray(D @ v),
                               rtol=1e-4, atol=1e-4)


def test_khatri_rao_square_factors_elementwise_square():
    n, d = 40, 2
    f = sq_euclidean_factors(jax.random.normal(KEY, (n, d)))
    sq = khatri_rao_square(f)
    assert sq.rank == f.rank ** 2
    np.testing.assert_allclose(np.asarray(sq.todense()),
                               np.asarray(f.todense() ** 2),
                               rtol=1e-4, atol=1e-4)


def test_sketch_factors_improve_with_rank():
    """Randomized range sketch: near-exact at full rank, error decreasing
    in the sketch rank."""
    n = 80
    x = jax.random.normal(KEY, (n, 3))
    C = Geometry.from_points(x, _uniform(n)).cost_matrix
    errs = {}
    for c in (8, 32, n):
        f = sketch_factors(C, c, jax.random.PRNGKey(2))
        errs[c] = float(jnp.linalg.norm(f.todense() - C)
                        / jnp.linalg.norm(C))
    assert errs[n] <= 1e-4
    assert errs[32] <= errs[8] + 1e-6
    assert errs[32] <= 0.5


# ---------------------------------------------------------------------------
# LR-Dykstra projection
# ---------------------------------------------------------------------------

def test_lr_dykstra_projects_onto_coupling_polytope():
    m, n, r = 80, 60, 6
    k1, k2, k3, ka, kb = jax.random.split(KEY, 5)
    K1 = jax.random.uniform(k1, (m, r), minval=0.1, maxval=1.0)
    K2 = jax.random.uniform(k2, (n, r), minval=0.1, maxval=1.0)
    k3v = jax.random.uniform(k3, (r,), minval=0.1, maxval=1.0)
    a = jax.random.dirichlet(ka, jnp.ones(m))
    b = jax.random.dirichlet(kb, jnp.ones(n))
    Q, R, g = lr_dykstra(K1, K2, k3v, a, b, 1e-10, 5000, 1e-9)
    assert float(jnp.abs(Q.sum(1) - a).sum()) < 1e-5
    assert float(jnp.abs(R.sum(1) - b).sum()) < 1e-5
    assert float(jnp.abs(Q.sum(0) - g).sum()) < 1e-5
    assert float(jnp.abs(R.sum(0) - g).sum()) < 1e-5
    np.testing.assert_allclose(float(g.sum()), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# solver accuracy (acceptance: ≤5% rel error vs converged dense_gw, n≤200)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rank", [4, 10, 75])
def test_lowrank_matches_dense_within_5pct_across_ranks(rank):
    """On a problem whose optimum is exactly low-rank (atom clusters +
    dilation), every rank r ≥ 4 must land within 5% of the converged
    dense_gw value — and of the closed-form optimum.

    The atoms construction is the honest test bed for small ranks: on
    generic clouds the plug-in value of a rank-4 coupling is dominated by
    the within-block residual the rank constraint itself imposes (and
    dense PGA is the unreliable side on most clustered seeds — it stalls
    at symmetric mixing fixed points ~3× above the optimum lowrank_gw
    finds; this seed is one where dense converges to the optimum too).
    """
    n = 150
    prob, assign = _atoms_problem(seed=1, n=n)
    ref = solve(_densified(prob), DENSE_REF)
    opt = _atoms_optimum(prob, assign)
    out = solve(prob, LowRankGWSolver(rank=rank), key=jax.random.PRNGKey(7))
    v = float(out.value)
    assert abs(v - float(ref.value)) / abs(float(ref.value)) <= 0.05
    assert abs(v - opt) / abs(opt) <= 0.05
    assert isinstance(out.coupling, LowRankCoupling)


def test_lowrank_halfrank_at_least_dense_quality_on_clouds():
    """r = n/2 on 2-D clouds: the plug-in objective must be within 5% of
    converged dense_gw *or better* (mirror descent routinely finds lower
    objectives than dense PGA here — both are local methods on a
    nonconvex problem, so only the upper side is a defect)."""
    for seed in (0, 1):
        prob = _cloud_problem(seed=seed, n=150)
        ref = float(solve(_densified(prob), DENSE_REF).value)
        out = solve(prob, LowRankGWSolver(rank=75),
                    key=jax.random.PRNGKey(7))
        assert float(out.value) <= 1.05 * ref, (
            f"seed {seed}: lowrank {float(out.value):.4f} vs dense "
            f"{ref:.4f}")
        # and the reported value is the true objective of the coupling
        T = out.coupling.todense()
        direct = float(gw_objective(prob.geom_x.cost_matrix,
                                    prob.geom_y.cost_matrix, T, "l2"))
        np.testing.assert_allclose(float(out.value), direct, rtol=1e-3)


def test_lowrank_coupling_feasibility():
    """ℓ1 marginal error of the output coupling < 1e-4 with a tight inner
    projection budget."""
    n = 120
    prob = _cloud_problem(seed=0, n=n)
    out = solve(prob, LowRankGWSolver(rank=10, **TIGHT),
                key=jax.random.PRNGKey(7))
    mu, nu = out.coupling.marginals()
    err = float(jnp.abs(mu - prob.geom_x.weights).sum()
                + jnp.abs(nu - prob.geom_y.weights).sum())
    assert err < 1e-4, f"marginal violation {err:.2e}"
    # g is a probability vector bounded away from rank collapse
    np.testing.assert_allclose(float(out.coupling.g.sum()), 1.0, rtol=1e-4)
    assert float(out.coupling.g.min()) >= 1e-10


def test_lowrank_sketch_path_matches_exact_path():
    """A dense-cost geometry (sketch path) must land near the point-cloud
    (exact-factor) path on the same problem when the sketch rank is
    saturating."""
    n = 100
    prob = _cloud_problem(seed=3, n=n)
    exact = solve(prob, LowRankGWSolver(rank=10), key=jax.random.PRNGKey(7))
    sk = solve(_densified(prob), LowRankGWSolver(rank=10, cost_rank=n),
               key=jax.random.PRNGKey(7))
    np.testing.assert_allclose(float(sk.value), float(exact.value),
                               rtol=2e-2)


def test_lowrank_kl_loss_runs():
    """kl is decomposable — the sketch path must handle its h = log C."""
    n = 40
    prob = _densified(_cloud_problem(seed=2, n=n))
    prob = QuadraticProblem(prob.geom_x, prob.geom_y, loss="kl")
    out = solve(prob, LowRankGWSolver(rank=6, outer_iters=30),
                key=jax.random.PRNGKey(7))
    assert np.isfinite(float(out.value))


# ---------------------------------------------------------------------------
# structure: registry, pytree leaves, jit+vmap
# ---------------------------------------------------------------------------

def test_lowrank_registered():
    assert "lowrank_gw" in repro.available_solvers()
    assert repro.get_solver("lowrank_gw") is LowRankGWSolver


def test_lowrank_requires_key():
    with pytest.raises(ValueError, match="PRNGKey"):
        solve(_cloud_problem(n=30), LowRankGWSolver(rank=4))


def test_lowrank_rejects_unsupported_variants():
    n = 30
    prob = _densified(_cloud_problem(n=n))
    with pytest.raises(NotImplementedError, match="balanced"):
        solve(QuadraticProblem(prob.geom_x, prob.geom_y, lam=1.0),
              LowRankGWSolver(rank=4), key=KEY)
    M = jnp.zeros((n, n))
    with pytest.raises(NotImplementedError, match="balanced"):
        solve(QuadraticProblem(prob.geom_x, prob.geom_y, M=M,
                               fused_penalty=0.5),
              LowRankGWSolver(rank=4), key=KEY)
    with pytest.raises(NotImplementedError, match="decomposable"):
        solve(QuadraticProblem(prob.geom_x, prob.geom_y, loss="l1"),
              LowRankGWSolver(rank=4), key=KEY)


def test_lowrank_epsilon_and_gamma_are_dynamic_leaves():
    """ε and γ sweeps must not retrace; static knobs must."""
    s1 = LowRankGWSolver(rank=8, epsilon=0.0, gamma=10.0)
    s2 = LowRankGWSolver(rank=8, epsilon=1e-2, gamma=30.0)
    l1_, t1 = jax.tree_util.tree_flatten(s1)
    l2_, t2 = jax.tree_util.tree_flatten(s2)
    assert t1 == t2
    assert l1_ == [0.0, 10.0] and l2_ == [1e-2, 30.0]
    _, t3 = jax.tree_util.tree_flatten(LowRankGWSolver(rank=16))
    assert t3 != t1


def test_lowrank_jit_vmap_stack_matches_per_problem():
    """Acceptance: composes with jax.jit + jax.vmap over a problem stack.

    tol=0 keeps batched and per-problem runs on identical control flow.
    """
    B, n = 3, 60
    solver = LowRankGWSolver(rank=6, outer_iters=25, inner_iters=100,
                             tol=0.0, inner_tol=0.0)
    probs = [_cloud_problem(seed=s, n=n) for s in range(B)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *probs)
    keys = jax.random.split(jax.random.PRNGKey(5), B)
    out = jax.jit(jax.vmap(lambda p, k: solve(p, solver, key=k)))(stacked,
                                                                  keys)
    assert out.value.shape == (B,)
    assert out.coupling.q.shape == (B, n, 6)
    for i in range(B):
        ref = solve(probs[i], solver, key=keys[i])
        np.testing.assert_allclose(float(out.value[i]), float(ref.value),
                                   rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(np.asarray(out.coupling.g[i]),
                                   np.asarray(ref.coupling.g), atol=1e-5)


def test_lowrank_entropic_step_finite_at_stationarity():
    """ε > 0 with vanishing gradients (here: identically-zero costs) is
    the worst case for the rescaled mirror step — γ = γ0/sup must not
    overflow f32 to inf (inf·0 = NaN) and the KL-prox exponent 1 - γε
    must stay clamped at 0 rather than flipping sign."""
    n = 20
    a = _uniform(n)
    z = jnp.zeros((n, 2))
    prob = QuadraticProblem(Geometry.from_points(z, a),
                            Geometry.from_points(z, a))
    for eps in (0.0, 1e-2):
        out = solve(prob, LowRankGWSolver(rank=4, epsilon=eps,
                                          outer_iters=10), key=KEY)
        assert np.isfinite(float(out.value))
        assert bool(jnp.all(jnp.isfinite(out.coupling.q)))
    # an exactly-zero marginal weight zeroes a Q row; with ε > 0 the
    # clamped exponent hits 0·log(floor) — the floor must be a normal
    # float32 (XLA CPU subnormal flush) or this NaNs
    aw = jnp.ones(n).at[5].set(0.0)
    aw = aw / aw.sum()
    x = jax.random.normal(jax.random.PRNGKey(3), (n, 2))
    y = jax.random.normal(jax.random.PRNGKey(4), (n, 2))
    pz = QuadraticProblem(Geometry.from_points(x, aw),
                          Geometry.from_points(y, _uniform(n)))
    out = solve(pz, LowRankGWSolver(rank=4, epsilon=5e-2, outer_iters=20),
                key=KEY)
    assert np.isfinite(float(out.value))


def test_lowrank_degenerate_weights_solve_is_finite():
    """~All mass on one point (mirrors test_sampling's edge case): the
    solve must stay finite and feasible."""
    n = 24
    a = jnp.full((n,), 1e-10).at[3].set(1.0)
    a = a / a.sum()
    kx, ky = jax.random.split(KEY)
    prob = QuadraticProblem(
        Geometry.from_points(jax.random.normal(kx, (n, 2)), a),
        Geometry.from_points(jax.random.normal(ky, (n, 2)), _uniform(n)))
    out = solve(prob, LowRankGWSolver(rank=4, outer_iters=30), key=KEY)
    assert np.isfinite(float(out.value))
    assert bool(jnp.all(jnp.isfinite(out.coupling.q)))
    mu, nu = out.coupling.marginals()
    assert float(jnp.abs(nu - _uniform(n)).sum()) < 1e-2


# ---------------------------------------------------------------------------
# LowRankCoupling container
# ---------------------------------------------------------------------------

def test_lowrank_coupling_container_contract():
    n = 50
    out = solve(_cloud_problem(seed=0, n=n), LowRankGWSolver(rank=5),
                key=KEY)
    c = out.coupling
    assert c.rank == 5
    T = c.todense(n, n)
    assert T.shape == (n, n)
    mu, nu = c.marginals(n, n)
    np.testing.assert_allclose(np.asarray(T.sum(1)), np.asarray(mu),
                               atol=1e-6)
    rows, cols, vals = c.tocoo()
    assert rows.shape == cols.shape == vals.shape == (n * n,)
    np.testing.assert_allclose(float(vals.sum()), float(T.sum()), rtol=1e-5)
    # apply == dense matvec, both axes
    v = jax.random.normal(jax.random.PRNGKey(2), (n,))
    np.testing.assert_allclose(np.asarray(c.apply(v)), np.asarray(T @ v),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(c.apply(v, axis=1)),
                               np.asarray(T.T @ v), atol=1e-6)
    # GWOutput.coupling_dense goes through todense
    np.testing.assert_array_equal(np.asarray(out.coupling_dense(n, n)),
                                  np.asarray(T))


# ---------------------------------------------------------------------------
# point-cloud Geometry
# ---------------------------------------------------------------------------

def test_point_cloud_geometry_cost_matrix():
    n, d = 30, 3
    x = jax.random.normal(KEY, (n, d))
    g = Geometry.from_points(x, _uniform(n))
    assert g.is_point_cloud and g.n == n
    D = jnp.sum((x[:, None] - x[None, :]) ** 2, -1)
    np.testing.assert_allclose(np.asarray(g.cost_matrix), np.asarray(D),
                               rtol=1e-4, atol=1e-5)


def test_point_cloud_geometry_validation():
    with pytest.raises(ValueError, match="points"):
        Geometry(None, _uniform(10))
    with pytest.raises(ValueError, match="weights"):
        Geometry.from_points(jnp.zeros((10, 2)), _uniform(11))
    # explicit cost + mismatched points
    with pytest.raises(ValueError, match="points"):
        Geometry(jnp.zeros((10, 10)), _uniform(10),
                 points=jnp.zeros((9, 2)))


def test_dense_solver_accepts_point_cloud_geometry():
    """Non-lowrank solvers materialize the cost from the points."""
    prob = _cloud_problem(seed=0, n=40)
    out = solve(prob, repro.DenseGWSolver(outer_iters=5, inner_iters=50))
    ref = solve(_densified(prob),
                repro.DenseGWSolver(outer_iters=5, inner_iters=50))
    np.testing.assert_allclose(float(out.value), float(ref.value),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# multiscale nesting (acceptance: lowrank_gw as QuantizedGWSolver.base)
# ---------------------------------------------------------------------------

def test_quantized_nests_lowrank_base_end_to_end():
    """lowrank_gw seeds the multiscale pipeline: the coarse anchor problem
    is solved low-rank, block_refine expands its densified coupling."""
    n = 120
    prob = _densified(_cloud_problem(seed=0, n=n))
    named = QuantizedGWSolver(k_x=24, k_y=24, base="lowrank_gw")
    assert isinstance(named.base, LowRankGWSolver)
    out = solve(prob, named, key=jax.random.PRNGKey(5))
    assert np.isfinite(float(out.value))
    mu, nu = out.coupling.marginals(n, n)
    # k ≪ n refinement keeps marginals only up to top-pair coverage of
    # the coarse coupling (ROADMAP known gap); the low-rank coarse
    # coupling at this k actually covers better than a dense base
    # (~0.22 vs ~0.98 ℓ1 here) — assert it stays in that regime
    assert float(jnp.abs(mu - prob.geom_x.weights).sum()
                 + jnp.abs(nu - prob.geom_y.weights).sum()) < 0.3
    # instance nesting with an explicit rank
    inst = QuantizedGWSolver(
        k_x=24, k_y=24, base=LowRankGWSolver(rank=8, outer_iters=100))
    assert np.isfinite(float(solve(prob, inst,
                                   key=jax.random.PRNGKey(5)).value))
