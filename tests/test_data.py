"""Data pipeline: determinism, shard slicing, checkpointable state."""
import numpy as np

from repro.configs import base as cb
from repro.data import TokenPipeline


def test_batches_deterministic():
    cfg = cb.get_reduced("smollm_135m")
    p1 = TokenPipeline(cfg, 32, 8)
    p2 = TokenPipeline(cfg, 32, 8)
    b1 = p1.global_batch_at(5)
    b2 = p2.global_batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.global_batch_at(6)["tokens"], b1["tokens"])


def test_labels_are_shifted_tokens():
    cfg = cb.get_reduced("smollm_135m")
    p = TokenPipeline(cfg, 32, 4)
    b = p.global_batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_shard_slices_partition_global_batch():
    cfg = cb.get_reduced("llama3_8b")
    p = TokenPipeline(cfg, 16, 8)
    g = p.global_batch_at(0)
    parts = [p.shard_slice(g, i, 4) for i in range(4)]
    recon = np.concatenate([x["tokens"] for x in parts], axis=0)
    np.testing.assert_array_equal(recon, g["tokens"])


def test_state_roundtrip_resumes_stream():
    cfg = cb.get_reduced("smollm_135m")
    p = TokenPipeline(cfg, 16, 4)
    next(p)
    next(p)
    state = p.state_dict()
    b3 = next(p)
    q = TokenPipeline(cfg, 16, 4)
    q.load_state_dict(state)
    np.testing.assert_array_equal(next(q)["tokens"], b3["tokens"])


def test_multicodebook_and_vlm_batches():
    cfg = cb.get_reduced("musicgen_medium")
    p = TokenPipeline(cfg, 16, 2)
    b = p.global_batch_at(0)
    assert b["tokens"].shape == (2, 16, cfg.n_codebooks)
    cfg = cb.get_reduced("llama_3_2_vision_90b")
    p = TokenPipeline(cfg, 16, 2)
    b = p.global_batch_at(0)
    assert b["image_embeds"].shape == (2, cfg.n_image_tokens, cfg.d_model)
