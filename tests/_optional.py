"""Import guards for optional test dependencies.

Test modules must not hard-import optional packages — a
ModuleNotFoundError at collection aborts the whole suite. Instead:

    from _optional import HAS_HYPOTHESIS, given, settings, st

    @pytest.mark.optional_dep("hypothesis")
    @settings(...)
    @given(st.integers(0, 100))
    def test_property(x): ...

When hypothesis is missing the stubs replace the test body with an
argless no-op and ``tests/conftest.py`` skips anything marked
``optional_dep("hypothesis")`` before it runs. Dev installs get the real
thing via requirements-dev.txt.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover — exercised w/o dev deps
    HAS_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(f):
            def _stub():          # argless: collectable; fails safe by
                import pytest     # skipping even without the marker
                pytest.skip("hypothesis not installed "
                            "(see requirements-dev.txt)")
            _stub.__name__ = f.__name__
            _stub.__doc__ = f.__doc__
            return _stub
        return deco

    def settings(*_a, **_k):
        return lambda f: f

    class _AnyStrategy:
        def __getattr__(self, _name):
            return lambda *_a, **_k: None

    st = _AnyStrategy()
