"""Per-architecture smoke tests: reduced configs, forward + one train step
on CPU, asserting output shapes and no NaNs — plus decode equivalence and
MoE dispatch-impl equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.models.moe import moe_mlp_gshard, moe_mlp_sort, moe_params
from repro.models.module import Builder
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    if cfg.n_codebooks > 1:
        toks = jax.random.randint(KEY, (B, S + 1, cfg.n_codebooks), 0,
                                  cfg.vocab_size)
    else:
        toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch_id", cb.ARCH_IDS)
def test_smoke_forward_and_train_step(arch_id):
    cfg = cb.get_reduced(arch_id)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits, hidden, aux = model.forward(params, batch["tokens"],
                                        img=batch.get("image_embeds"))
    B, S = batch["tokens"].shape[0], batch["tokens"].shape[1]
    if cfg.n_codebooks > 1:
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert hidden.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.array(logits)).all()

    step = make_train_step(model, act_dtype=jnp.float32, remat=False,
                           total_steps=10)
    opt = adamw.init(params)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(metrics["loss"]), arch_id
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert delta > 0


@pytest.mark.parametrize("arch_id", ["llama3_8b", "minicpm3_4b",
                                     "xlstm_125m", "zamba2_7b",
                                     "musicgen_medium"])
def test_decode_matches_forward(arch_id):
    cfg = cb.get_reduced(arch_id)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 8
    if cfg.n_codebooks > 1:
        tokens = jax.random.randint(KEY, (B, S, cfg.n_codebooks), 0,
                                    cfg.vocab_size)
    else:
        tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _, _ = model.forward(params, tokens)
    cache = model.init_cache(B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, tokens[:, t:t + 1], cache,
                                      jnp.int32(t), act_dtype=jnp.float32)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.array(dec), np.array(full), atol=2e-2,
                               rtol=1e-2)


def test_prefill_matches_forward_last_logit():
    cfg = cb.get_reduced("llama3_8b")
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    full, _, _ = model.forward(params, tokens)
    pre, cache = model.prefill(params, tokens, act_dtype=jnp.float32)
    np.testing.assert_allclose(np.array(pre), np.array(full[:, -1:]),
                               atol=1e-4)
    assert jax.tree.leaves(cache)  # caches produced


def test_moe_impls_agree_dropless():
    cfg = cb.get_reduced("phi3_5_moe_42b_a6_6b")
    b = Builder("init", KEY)
    p = moe_params(b, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y1, _ = moe_mlp_gshard(p, cfg, x, no_drop=True)
    y2, _ = moe_mlp_sort(p, cfg, x, no_drop=True)
    np.testing.assert_allclose(np.array(y1), np.array(y2), atol=1e-4,
                               rtol=1e-4)


def test_blockwise_attention_matches_einsum_path():
    cfg = cb.get_reduced("llama3_8b")
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (2, 64), 0, cfg.vocab_size)
    ref, _, _ = model.forward(params, tokens, use_flash=False)
    from repro.models.attention import set_flash_chunk
    set_flash_chunk(16)
    got, _, _ = model.forward(params, tokens, use_flash=True)
    set_flash_chunk(512)
    np.testing.assert_allclose(np.array(got), np.array(ref), atol=2e-3,
                               rtol=1e-3)


def test_blockwise_mla_matches_einsum_path():
    cfg = cb.get_reduced("minicpm3_4b")
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (2, 64), 0, cfg.vocab_size)
    ref, _, _ = model.forward(params, tokens, use_flash=False)
    from repro.models.attention import set_flash_chunk
    set_flash_chunk(16)
    got, _, _ = model.forward(params, tokens, use_flash=True)
    set_flash_chunk(512)
    np.testing.assert_allclose(np.array(got), np.array(ref), atol=2e-3,
                               rtol=1e-3)


def test_gw_align_loss_trains():
    """The paper's technique as a training feature: loss is finite and
    differentiable through the unrolled Sinkhorn."""
    cfg = cb.get_reduced("smollm_135m")
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg, B=2, S=64)
    step = make_train_step(model, act_dtype=jnp.float32, remat=False,
                           gw_align=True, total_steps=10)
    opt = adamw.init(params)
    _, _, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(metrics["loss"])
