"""Sinkhorn solver unit + property tests (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional import given, settings, st  # guarded hypothesis import

from repro.core.sinkhorn import (
    segment_logsumexp,
    sinkhorn,
    sinkhorn_log,
    sinkhorn_unbalanced,
    sinkhorn_unbalanced_log,
    sparse_sinkhorn,
    sparse_sinkhorn_logdomain,
)

KEY = jax.random.PRNGKey(0)


def _simplex(key, n):
    x = jax.random.uniform(key, (n,)) + 0.1
    return x / x.sum()


def test_sinkhorn_marginals():
    m, n = 24, 17
    a = _simplex(KEY, m)
    b = _simplex(jax.random.PRNGKey(1), n)
    K = jax.random.uniform(jax.random.PRNGKey(2), (m, n)) + 0.05
    T = sinkhorn(a, b, K, 200)
    np.testing.assert_allclose(np.array(T.sum(1)), np.array(a), rtol=1e-4)
    np.testing.assert_allclose(np.array(T.sum(0)), np.array(b), rtol=1e-4)


def test_log_domain_matches_plain():
    m, n = 16, 16
    a = _simplex(KEY, m)
    b = _simplex(jax.random.PRNGKey(1), n)
    K = jax.random.uniform(jax.random.PRNGKey(2), (m, n)) + 0.05
    T1 = sinkhorn(a, b, K, 60)
    T2 = sinkhorn_log(a, b, jnp.log(K), 60)
    np.testing.assert_allclose(np.array(T1), np.array(T2), atol=1e-5)


def test_log_domain_survives_small_epsilon():
    """Plain domain underflows at eps=1e-3 with O(1) costs; log domain must
    still satisfy marginals."""
    m = 32
    a = _simplex(KEY, m)
    b = _simplex(jax.random.PRNGKey(1), m)
    C = jax.random.uniform(jax.random.PRNGKey(2), (m, m)) * 5.0
    T = sinkhorn_log(a, b, -C / 1e-3, 300)
    assert np.isfinite(np.array(T)).all()
    np.testing.assert_allclose(np.array(T.sum(0)), np.array(b), rtol=1e-3)


def test_unbalanced_log_matches_plain():
    m, n = 12, 14
    a = jax.random.uniform(KEY, (m,)) + 0.2
    b = jax.random.uniform(jax.random.PRNGKey(1), (n,)) + 0.2
    K = jax.random.uniform(jax.random.PRNGKey(2), (m, n)) + 0.1
    T1 = sinkhorn_unbalanced(a, b, K, 1.0, 0.1, 80)
    T2 = sinkhorn_unbalanced_log(a, b, jnp.log(K), 1.0, 0.1, 80)
    np.testing.assert_allclose(np.array(T1), np.array(T2), atol=1e-5)


def test_sparse_matches_dense_on_full_support():
    """COO Sinkhorn on the full index set == dense Sinkhorn."""
    m, n = 9, 7
    a = _simplex(KEY, m)
    b = _simplex(jax.random.PRNGKey(1), n)
    K = jax.random.uniform(jax.random.PRNGKey(2), (m, n)) + 0.05
    rows, cols = jnp.meshgrid(jnp.arange(m), jnp.arange(n), indexing="ij")
    rows, cols = rows.reshape(-1), cols.reshape(-1)
    vals = K[rows, cols]
    T_dense = sinkhorn(a, b, K, 100)
    t_sparse = sparse_sinkhorn(a, b, rows, cols, vals, m, n, 100)
    np.testing.assert_allclose(np.array(T_dense[rows, cols]),
                               np.array(t_sparse), rtol=1e-5, atol=1e-8)
    t_log = sparse_sinkhorn_logdomain(a, b, rows, cols, jnp.log(vals), m, n,
                                      100)
    np.testing.assert_allclose(np.array(t_sparse), np.array(t_log),
                               rtol=1e-4, atol=1e-7)


def test_segment_logsumexp_matches_dense():
    vals = jnp.array([0.0, 1.0, -2.0, 3.0, 0.5])
    segs = jnp.array([0, 0, 2, 2, 2])
    out = segment_logsumexp(vals, segs, 4)
    expect0 = np.logaddexp(0.0, 1.0)
    expect2 = np.log(np.exp(-2.0) + np.exp(3.0) + np.exp(0.5))
    assert np.allclose(out[0], expect0)
    assert np.allclose(out[2], expect2)
    assert out[1] < -1e29 and out[3] < -1e29  # empty segments


@pytest.mark.optional_dep("hypothesis")
@settings(max_examples=15, deadline=None)
@given(st.integers(4, 20), st.integers(4, 20), st.integers(0, 1000))
def test_property_marginals_and_nonnegativity(m, n, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    a = _simplex(k1, m)
    b = _simplex(k2, n)
    K = jax.random.uniform(k3, (m, n)) + 0.05
    T = sinkhorn(a, b, K, 150)
    T = np.array(T)
    assert (T >= -1e-9).all()
    np.testing.assert_allclose(T.sum(0), np.array(b), rtol=5e-3)
    # scaling invariance: gamma*K gives the same coupling
    T2 = np.array(sinkhorn(a, b, 3.7 * K, 150))
    np.testing.assert_allclose(T, T2, rtol=1e-4, atol=1e-8)
