"""Fig. 2: GW estimation error (vs PGA-GW benchmark) and CPU time vs n,
on Moon and Graph, for l1 and l2 ground costs.

Methods: EGW, PGA-GW (benchmark), SaGroW, SPAR-GW (paper), Grid-SPAR-GW
(beyond-paper TPU-native variant). s = 16 n, s' = s²/n² (equal budget),
estimates averaged over runs — the paper's protocol.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FULL, record, timed
from benchmarks.datasets import DATASETS
from repro.core import egw, grid_spar_gw, pga_gw, sagrow, spar_gw


def run(dataset: str = "moon", losses=("l2", "l1"), ns=None, reps: int = 3,
        R: int = 10, H: int = 30):
    ns = ns or ([100, 200, 500] if FULL else [60, 120])
    results = []
    for loss in losses:
        for n in ns:
            a, b, Cx, Cy = DATASETS[dataset](n)
            a, b = jnp.asarray(a), jnp.asarray(b)
            Cx, Cy = jnp.asarray(Cx), jnp.asarray(Cy)
            kw = dict(loss=loss, epsilon=1e-2, outer_iters=R, inner_iters=H)

            t_ref, (ref, _) = timed(lambda: pga_gw(a, b, Cx, Cy, **kw))
            record(f"fig2/{dataset}/{loss}/n{n}/pga_gw", t_ref * 1e6,
                   f"value={float(ref):.5f}")

            t_e, (v_e, _) = timed(lambda: egw(a, b, Cx, Cy, **kw))
            record(f"fig2/{dataset}/{loss}/n{n}/egw", t_e * 1e6,
                   f"err={abs(float(v_e) - float(ref)):.5f}")

            s = 16 * n
            for name, fn in [
                ("spar_gw", lambda k: spar_gw(k, a, b, Cx, Cy, s=s, **kw)),
                ("grid_spar_gw", lambda k: grid_spar_gw(
                    k, a, b, Cx, Cy, s_r=int(np.sqrt(s)), s_c=int(np.sqrt(s)),
                    **kw)),
                ("sagrow", lambda k: sagrow(k, a, b, Cx, Cy,
                                            s_prime=max(s * s // (n * n), 16),
                                            **kw)),
            ]:
                vals, t_acc = [], 0.0
                for r in range(reps):
                    t, (v, _) = timed(fn, jax.random.PRNGKey(r),
                                      warmup=(r == 0))
                    vals.append(float(v))
                    t_acc += t
                err = abs(np.mean(vals) - float(ref))
                record(f"fig2/{dataset}/{loss}/n{n}/{name}",
                       t_acc / reps * 1e6,
                       f"err={err:.5f};std={np.std(vals):.5f}")
                results.append((dataset, loss, n, name, err, t_acc / reps))
    return results


def main():
    run("moon")
    run("graph")


if __name__ == "__main__":
    main()
