"""Observability smoke — the CI obs-smoke job's assertion script.

Exercises every telemetry surface end to end and asserts on it:

1. one **traced solve per registered family** — the trace exists, its
   recorded prefix is finite where the contract says so, and its length
   equals ``n_iters``;
2. ``obs.report()`` after a traced solve — one ``json.dumps``-clean
   document with the trace, the span breakdown, and the registry
   snapshot;
3. ``bench_serve --quick`` **with the Prometheus exporter live** — the
   serve rows stay finite, a real scrape of ``/metrics`` returns valid
   exposition text (``validate_exposition``), and the registry snapshot
   round-trips through strict JSON;
4. trace=off stays **bitwise identical** to the traced coupling.

Run: ``PYTHONPATH=src python benchmarks/obs_smoke.py``
"""
from __future__ import annotations

import dataclasses
import json
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro import obs

N = 24


def _problem(seed=0, n=N):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))

    def cloud(key, scale):
        x = jax.random.normal(key, (n, 2)) * scale
        return jnp.sqrt(jnp.sum((x[:, None] - x[None, :]) ** 2, -1))

    a = jnp.ones(n) / n
    return repro.QuadraticProblem(repro.Geometry(cloud(kx, 1.0), a),
                                  repro.Geometry(cloud(ky, 1.2), a),
                                  loss="l2")


def traced_solve_per_family() -> None:
    problem = _problem()
    for name in repro.available_solvers():
        solver = dataclasses.replace(
            repro.get_solver(name).default_config(N), trace=True)
        key = (jax.random.PRNGKey(7)
               if getattr(type(solver), "requires_key", False) else None)
        out = repro.solve(problem, solver, key=key, validate=False)
        assert out.trace is not None, f"{name}: no trace with trace=True"
        n = int(out.n_iters)
        nv = obs.n_valid(out.trace)
        assert nv == n > 0, f"{name}: n_valid {nv} != n_iters {n}"
        err = np.asarray(out.trace.err)[:n]
        assert np.all(np.isfinite(err[~np.isnan(err)])), \
            f"{name}: inf in the err trace"
        doc = obs.trace_to_dict(out.trace, n)
        json.dumps(doc)
        print(f"obs_smoke/trace/{name},0.0,"
              f"n_iters={n};final_err={doc['err'][-1]}")


def report_roundtrip() -> None:
    obs.clear_spans()
    problem = _problem(seed=3)
    solver = repro.DenseGWSolver(tol=1e-6, inner_tol=1e-8, outer_iters=10,
                                 trace=True)
    out = repro.solve(problem, solver, on_failure="raise")
    doc = obs.report(out, solver="dense_gw")
    assert set(doc) == {"solve", "spans", "breakdown", "metrics"}
    assert doc["solve"]["trace"] is not None
    assert any(r["name"] == "solve.dispatch" for r in doc["spans"])
    total = doc["breakdown"]["compile_s"] + doc["breakdown"]["dispatch_s"]
    assert total > 0, "lifecycle breakdown recorded no dispatch time"
    payload = json.dumps(doc)
    assert json.loads(payload)["solve"]["n_iters"] == doc["solve"]["n_iters"]
    print(f"obs_smoke/report,0.0,spans={len(doc['spans'])};"
          f"compile_s={doc['breakdown']['compile_s']:.3f}")


def trace_off_bitwise() -> None:
    problem = _problem(seed=5)
    base = repro.DenseGWSolver(outer_iters=6, tol=0.0, inner_tol=1e-8)
    out_off = repro.solve(problem, base, validate=False)
    out_on = repro.solve(problem, dataclasses.replace(base, trace=True),
                         validate=False)
    assert out_off.trace is None
    np.testing.assert_array_equal(np.asarray(out_off.coupling_dense(N, N)),
                                  np.asarray(out_on.coupling_dense(N, N)))
    print("obs_smoke/bitwise_off,0.0,ok")


def serve_with_exporter() -> None:
    from benchmarks import bench_serve
    http = obs.serve_metrics_http(0)          # ephemeral port
    try:
        rows = bench_serve.main(quick=True, json_path="")
        for row in rows:
            assert np.isfinite(row["p99_ms"]), f"non-finite p99: {row}"
        host, port = http.server_address[:2]
        with urllib.request.urlopen(
                f"http://{host}:{port}/metrics", timeout=10) as resp:
            text = resp.read().decode()
        n_samples = obs.validate_exposition(text)
        assert n_samples > 0
        assert "repro_serve_requests_total" in text
        snap = json.loads(json.dumps(obs.registry().snapshot()))
        assert "repro_serve_latency_seconds" in snap["metrics"]
        print(f"obs_smoke/serve_exporter,0.0,samples={n_samples}")
    finally:
        http.shutdown()


def main() -> None:
    traced_solve_per_family()
    report_roundtrip()
    trace_off_bitwise()
    serve_with_exporter()
    print("obs_smoke/ok,0.0,all checks passed")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
