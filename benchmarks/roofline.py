"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell:
  compute term    = FLOPs_per_device / peak_FLOP/s        (197 TF bf16, v5e)
  memory term     = bytes_per_device / HBM_bw             (819 GB/s)
  collective term = wire_bytes_per_device / ICI_bw        (50 GB/s/link;
                    HLO is the per-device program, so per-device wire bytes
                    over per-chip link bw == global_bytes/(chips·link_bw))
plus MODEL_FLOPS = 6·N·D (train) / 2·N·D (fwd) vs compiled FLOPs.
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
AUTOTUNE_ART = Path(__file__).resolve().parents[1] / "artifacts" / "autotune"

SHAPE_TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
                "decode_32k": 128, "long_500k": 1}


def model_flops(rec) -> float:
    """6·N·D for train, 2·N·D forward-only (decode: D = batch tokens)."""
    if rec["kind"] == "gw" or rec["shape"] not in SHAPE_TOKENS:
        return 0.0
    n = rec["n_params"]
    toks = SHAPE_TOKENS[rec["shape"]]
    mult = 6.0 if rec["kind"] == "train" else 2.0
    # MoE: active params only
    arch = rec["arch"]
    active_frac = 1.0
    if "llama4-scout" in arch:
        active_frac = (1 + 2) / 17.0 * 1.7      # ~2 of 17B active (top1+shared)
    if "phi3.5-moe" in arch:
        active_frac = 6.6 / 42.0
    return mult * n * active_frac * toks


def load_cells(mesh: str = None, tag: str = ""):
    cells = []
    for p in sorted(ART.glob("*.json")):
        with open(p) as f:
            rec = json.load(f)
        if mesh and rec.get("mesh") != mesh:
            continue
        if rec.get("tag", "") != tag:
            continue
        cells.append(rec)
    return cells


def analyze(rec):
    chips = 1
    for v in rec["mesh_shape"].values():
        chips *= v
    t_comp = rec["flops_per_device"] / PEAK_FLOPS
    t_mem = rec["bytes_per_device"] / HBM_BW
    wire = sum(v["wire_bytes"] for v in rec["collectives"].values())
    t_coll = wire / ICI_BW
    dom = max((("compute", t_comp), ("memory", t_mem),
               ("collective", t_coll)), key=lambda kv: kv[1])[0]
    mf = model_flops(rec)
    hlo_global = rec["flops_per_device"] * chips
    ratio = mf / hlo_global if hlo_global else 0.0
    bound = max(t_comp, t_mem, t_coll)
    frac = t_comp / bound if bound else 0.0   # roofline fraction (compute/limit)
    return {"arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "dominant": dom,
            "model_flops": mf, "hlo_flops_global": hlo_global,
            "useful_ratio": ratio, "roofline_fraction": frac,
            "temp_GiB": rec["memory"]["temp_bytes"] / 2**30,
            "args_GiB": rec["memory"]["argument_bytes"] / 2**30}


def table(mesh="single", tag=""):
    rows = [analyze(r) for r in load_cells(mesh, tag)]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def autotune_table():
    """Kernel micro-autotune records (written via repro.kernels.dispatch
    by the benchmarks, e.g. bench_spar_cost). One row per sweep."""
    rows = []
    for p in sorted(AUTOTUNE_ART.glob("*.json")) if AUTOTUNE_ART.exists() \
            else []:
        with open(p) as f:
            rows.extend(json.load(f))
    return rows


def main():
    tune = autotune_table()
    if tune:
        print("\n=== kernel autotune (dispatch records) ===")
        print(f"{'family':18s} {'backend':8s} {'best':>6s}  timings")
        for r in tune:
            timings = " ".join(f"{k}:{v*1e6:.0f}us"
                               for k, v in sorted(r["timings_s"].items(),
                                                  key=lambda kv: int(kv[0])))
            print(f"{r['family']:18s} {r['backend']:8s} "
                  f"{r['best_block']:6d}  {timings}")
    for mesh in ("single", "multi"):
        rows = table(mesh)
        if not rows:
            continue
        print(f"\n=== mesh: {mesh} ===")
        hdr = (f"{'arch':26s} {'shape':12s} {'comp(s)':>9s} {'mem(s)':>9s} "
               f"{'coll(s)':>9s} {'dominant':>10s} {'6ND/HLO':>8s} "
               f"{'frac':>6s} {'temp':>7s}")
        print(hdr)
        for r in rows:
            print(f"{r['arch']:26s} {r['shape']:12s} "
                  f"{r['t_compute_s']:9.2e} {r['t_memory_s']:9.2e} "
                  f"{r['t_collective_s']:9.2e} {r['dominant']:>10s} "
                  f"{r['useful_ratio']:8.2f} {r['roofline_fraction']:6.2f} "
                  f"{r['temp_GiB']:6.1f}G")


if __name__ == "__main__":
    main()
