"""Fig. 3: unbalanced GW — naive plan, PGA-UGW (benchmark), SPAR-UGW.
Unit total masses, λ = 1 (paper §6.1.1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FULL, record, timed
from benchmarks.datasets import DATASETS
from repro.core import spar_ugw, ugw_dense
from repro.core.spar_ugw import naive_ugw_value


def run(dataset: str = "moon", losses=("l2", "l1"), ns=None, reps: int = 3):
    ns = ns or ([100, 200] if FULL else [60, 120])
    for loss in losses:
        for n in ns:
            a, b, Cx, Cy = DATASETS[dataset](n)
            a, b = jnp.asarray(a), jnp.asarray(b)
            Cx, Cy = jnp.asarray(Cx), jnp.asarray(Cy)
            kw = dict(loss=loss, lam=1.0, epsilon=1e-2, outer_iters=10,
                      inner_iters=30)
            t_ref, (ref, _) = timed(lambda: ugw_dense(a, b, Cx, Cy, **kw))
            record(f"fig3/{dataset}/{loss}/n{n}/pga_ugw", t_ref * 1e6,
                   f"value={float(ref):.5f}")
            t_n, v_n = timed(lambda: naive_ugw_value(a, b, Cx, Cy,
                                                     loss=loss, lam=1.0))
            record(f"fig3/{dataset}/{loss}/n{n}/naive", t_n * 1e6,
                   f"err={abs(float(v_n) - float(ref)):.5f}")
            vals, t_acc = [], 0.0
            for r in range(reps):
                t, (v, _) = timed(
                    lambda k: spar_ugw(k, a, b, Cx, Cy, s=16 * n, **kw),
                    jax.random.PRNGKey(r), warmup=(r == 0))
                vals.append(float(v))
                t_acc += t
            record(f"fig3/{dataset}/{loss}/n{n}/spar_ugw", t_acc / reps * 1e6,
                   f"err={abs(np.mean(vals) - float(ref)):.5f}")


def main():
    run("moon")
    run("graph", losses=("l2",))


if __name__ == "__main__":
    main()
