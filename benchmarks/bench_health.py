"""Fault-injection recovery-rate benchmark for the health layer.

Sweeps a fault matrix (solver family × fault kind × site × transience)
on a fixed moon-dataset problem and classifies every cell:

  * silent      — output is non-finite / mass-collapsed but the status
                  says healthy. The bug class this layer exists to
                  kill; the silent rate must be 0.
  * detected    — solve came back flagged (DIVERGED / STALLED); for
                  these the bench then measures the fallback ladder
                  (fraction recovered to a finite healthy coupling by
                  ``solve(..., on_failure="fallback")``).
  * rescued     — transient fault absorbed in-jit by an ε-rescue
                  restart (healthy status, n_rescues ≥ 1, finite);
  * self-healed — fault neutralized by the algorithm itself (e.g. an
                  "overflow"-scaled or zeroed iterate renormalized by
                  the next Sinkhorn marginal projection) — a benign
                  outcome, not a miss.

For ``quantized_gw`` the fault is injected into the nested coarse
``base`` solver (its own ``fault`` field targets only the short polish
loop; the coarse solve is where mid-pipeline divergence lives).

The EXPERIMENTS.md §"Health & recovery" table is generated from this
run. Wall-time per cell is also recorded (the health machinery's cost
is the difference against the fault-free baseline).

  python benchmarks/bench_health.py            # full matrix, n=60
  python benchmarks/bench_health.py --quick    # nan/inf × iterate only

Appends its records to BENCH_PR6.json (--json '' disables).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

from benchmarks.common import merge_bench_json, record

SOLVERS = ("dense_gw", "spar_gw", "grid_gw", "lowrank_gw", "quantized_gw")
FAULT_ITER = 2


def _configs(n: int):
    import repro
    return {
        "dense_gw": repro.DenseGWSolver(tol=1e-6, inner_tol=1e-8,
                                        outer_iters=10),
        "spar_gw": repro.SparGWSolver(s=8 * n, outer_iters=10,
                                      inner_tol=1e-8),
        "grid_gw": repro.GridGWSolver(s_r=16, s_c=16, outer_iters=10,
                                      inner_tol=1e-8),
        "lowrank_gw": repro.LowRankGWSolver(outer_iters=40),
        "quantized_gw": repro.QuantizedGWSolver(refine_iters=50,
                                                polish_iters=2,
                                                polish_inner_iters=50),
    }


def _is_finite_out(out, n: int) -> bool:
    import numpy as np
    T = np.asarray(out.coupling_dense(n, n))
    return bool(np.all(np.isfinite(T)) and np.abs(T).sum() > 1e-12
                and np.isfinite(float(out.value)))


def _with_fault(base, fault, max_rescues):
    """Attach a fault to a solver config — on the nested coarse base for
    quantized (see module docstring), directly otherwise."""
    if type(base).name == "quantized_gw":
        return dataclasses.replace(
            base, base=dataclasses.replace(base.base, fault=fault,
                                           max_rescues=max_rescues))
    return dataclasses.replace(base, fault=fault, max_rescues=max_rescues)


def main(quick: bool = False, n: int = 60,
         json_path: str = "BENCH_PR6.json") -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro
    from benchmarks.datasets import DATASETS
    from repro.health import FaultSpec

    kinds = ("nan", "inf") if quick else ("nan", "inf", "overflow", "zero")
    sites = ("iterate",) if quick else ("iterate", "cost")
    key = jax.random.PRNGKey(0)

    a, b, Cx, Cy = map(jnp.asarray, DATASETS["moon"](n))
    problem = repro.QuadraticProblem(repro.Geometry(Cx, a),
                                     repro.Geometry(Cy, b), loss="l2")

    results = []
    for name, base in _configs(n).items():
        silent = detected = rescued = self_healed = fell_back = 0
        n_cells = 0
        t0 = time.time()
        for kind in kinds:
            for site in sites:
                for persistent in (False, True):
                    n_cells += 1
                    fault = FaultSpec(at_iter=FAULT_ITER, kind=kind,
                                      site=site, persistent=persistent)
                    # transient faults exercise the in-jit rescue path;
                    # persistent ones exhaust it and exercise fallback
                    solver = _with_fault(
                        base, fault, max_rescues=0 if persistent else 2)
                    out = repro.solve(problem, solver, key=key)
                    flagged = bool(np.any(np.asarray(out.status.code) >= 2))
                    n_resc = int(np.max(np.asarray(out.status.n_rescues)))
                    finite = _is_finite_out(out, n)
                    if flagged:
                        detected += 1
                        fb = repro.solve(problem, solver, key=key,
                                         on_failure="fallback")
                        if (not bool(np.any(
                                np.asarray(fb.status.code) >= 2))
                                and _is_finite_out(fb, n)):
                            fell_back += 1
                    elif not finite:
                        silent += 1          # healthy status, broken output
                    elif n_resc > 0:
                        rescued += 1
                    else:
                        self_healed += 1
        wall = time.time() - t0
        row = {
            "solver": name,
            "dataset": "health-faults",
            "n": n,
            "fault_cells": n_cells,
            "silent": silent,
            "detected": detected,
            "rescued": rescued,
            "self_healed": self_healed,
            "fallback_recovered": fell_back,
            "fallback_rate": round(fell_back / max(detected, 1), 3),
            "wall_time_s": round(wall, 3),
        }
        results.append(row)
        record(f"health/faults/n{n}/{name}", wall * 1e6 / n_cells,
               f"silent={silent};detected={detected};rescued={rescued};"
               f"self_healed={self_healed};"
               f"fallback={fell_back}/{detected};cells={n_cells}")
    if json_path:
        merge_bench_json(json_path, "health-faults", results)
    total_silent = sum(r["silent"] for r in results)
    print(f"# silent corruption cells: {total_silent} "
          f"(must be 0 across {sum(r['fault_cells'] for r in results)})")
    if total_silent:
        raise SystemExit(1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="nan/inf × iterate-site only (CI smoke)")
    ap.add_argument("--n", type=int, default=60)
    ap.add_argument("--json", default="BENCH_PR6.json")
    print("name,us_per_call,derived")
    args = ap.parse_args()
    main(quick=args.quick, n=args.n, json_path=args.json)
