"""Tables 2-3: graph clustering (Rand index) and classification (accuracy)
from pairwise SPAR-GW distances.

Offline substitution (DESIGN.md §8): TU datasets / PyG / sklearn are not
available, so we generate a 3-class synthetic corpus (SBM 2-block, SBM
3-block, Barabási–Albert) with identical protocol shape: pairwise (F)GW
distance matrix D -> similarity S = exp(-D/γ) -> spectral clustering (own
eigh+k-means) for RI, kernel-ridge one-vs-rest for accuracy.
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import networkx as nx
import numpy as np
from scipy.linalg import eigh

from benchmarks.common import FULL, record, timed
from repro.core import spar_gw


# ---------------------------------------------------------------------------
# corpus
# ---------------------------------------------------------------------------

def make_corpus(n_per_class: int, n_nodes: int, seed: int = 0):
    graphs, labels = [], []
    rng = np.random.default_rng(seed)
    for i in range(n_per_class):
        g = nx.stochastic_block_model(
            [n_nodes // 2, n_nodes - n_nodes // 2], [[0.6, 0.05], [0.05, 0.6]],
            seed=int(rng.integers(1e6)))
        graphs.append(g); labels.append(0)
        sizes = [n_nodes // 3, n_nodes // 3, n_nodes - 2 * (n_nodes // 3)]
        p = [[0.7, 0.05, 0.05], [0.05, 0.7, 0.05], [0.05, 0.05, 0.7]]
        g = nx.stochastic_block_model(sizes, p, seed=int(rng.integers(1e6)))
        graphs.append(g); labels.append(1)
        g = nx.barabasi_albert_graph(n_nodes, 3, seed=int(rng.integers(1e6)))
        graphs.append(g); labels.append(2)
    return graphs, np.array(labels)


def graph_repr(g):
    A = nx.to_numpy_array(g).astype(np.float32)
    d = A.sum(1) + 1e-9
    return jnp.asarray(A), jnp.asarray(d / d.sum(), jnp.float32)


# ---------------------------------------------------------------------------
# own spectral clustering + kernel ridge (sklearn unavailable offline)
# ---------------------------------------------------------------------------

def kmeans(X, k, iters=50, seed=0):
    rng = np.random.default_rng(seed)
    centers = X[rng.choice(len(X), k, replace=False)]
    for _ in range(iters):
        d = ((X[:, None] - centers[None]) ** 2).sum(-1)
        assign = d.argmin(1)
        for j in range(k):
            if (assign == j).any():
                centers[j] = X[assign == j].mean(0)
    return assign


def spectral_clustering(S, k, seed=0):
    d = S.sum(1)
    Dm = np.diag(1.0 / np.sqrt(d + 1e-12))
    L = np.eye(len(S)) - Dm @ S @ Dm
    w, v = eigh(L)
    emb = v[:, :k]
    emb = emb / (np.linalg.norm(emb, axis=1, keepdims=True) + 1e-12)
    return kmeans(emb, k, seed=seed)


def rand_index(y_true, y_pred):
    n = len(y_true)
    same_t = y_true[:, None] == y_true[None, :]
    same_p = y_pred[:, None] == y_pred[None, :]
    agree = (same_t == same_p).sum() - n
    return agree / (n * (n - 1))


def kernel_ridge_cv(S, y, n_classes, folds=5, lam=1e-3, seed=0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(y))
    accs = []
    for f in range(folds):
        test = idx[f::folds]
        train = np.setdiff1d(idx, test)
        K_tr = S[np.ix_(train, train)]
        Y = np.eye(n_classes)[y[train]]
        alpha = np.linalg.solve(K_tr + lam * np.eye(len(train)), Y)
        pred = S[np.ix_(test, train)] @ alpha
        accs.append((pred.argmax(1) == y[test]).mean())
    return float(np.mean(accs))


# ---------------------------------------------------------------------------

def main():
    n_per = 8 if FULL else 4
    n_nodes = 30
    graphs, labels = make_corpus(n_per, n_nodes)
    reprs = [graph_repr(g) for g in graphs]
    N = len(graphs)
    s = 8 * n_nodes

    for loss in (("l1", "l2") if FULL else ("l1",)):
        import time
        t0 = time.time()
        D = np.zeros((N, N))
        key = jax.random.PRNGKey(0)
        for i, j in itertools.combinations(range(N), 2):
            Ai, ai = reprs[i]
            Aj, aj = reprs[j]
            v, _ = spar_gw(jax.random.fold_in(key, i * N + j), ai, aj, Ai, Aj,
                           s=s, loss=loss, epsilon=1e-2, outer_iters=8,
                           inner_iters=20)
            D[i, j] = D[j, i] = max(float(v), 0.0)
        dt = time.time() - t0
        best_ri, best_acc = 0.0, 0.0
        for gamma in (np.median(D[D > 0]) * g for g in (0.25, 0.5, 1.0, 2.0)):
            S = np.exp(-D / gamma)
            pred = spectral_clustering(S, 3)
            best_ri = max(best_ri, rand_index(labels, pred))
            best_acc = max(best_acc, kernel_ridge_cv(S, labels, 3))
        record(f"tables23/{loss}/rand_index", dt / (N * (N - 1) / 2) * 1e6,
               f"RI={best_ri:.4f}")
        record(f"tables23/{loss}/accuracy", dt / (N * (N - 1) / 2) * 1e6,
               f"acc={best_acc:.4f}")


if __name__ == "__main__":
    main()
