"""Differentiable-GW benchmark: envelope backward vs unrolled autodiff.

Three record families, merged into BENCH_PR10.json (dataset "diff"):

* ``backward/*`` — implicit (Danskin envelope) vs unrolled (lax.scan
  backprop) gradient cost at n ≥ 1000: wall time and the compiled
  executable's temp-buffer footprint (``memory_analysis()`` on the AOT
  artifact — the unrolled dense backward wants tens of GB of residuals,
  which is exactly the point, so it is *measured without running* and
  executed only when the projected footprint fits comfortably).
* ``lowrank_init/*`` — anchors-seeded vs random (Q, R, g) init at the
  default 300-step budget: final GW-LR value and convergence flag.
* ``barycenter/*`` — free-support descent trajectory on two gaussian
  clouds; records the objective curve, a monotone-descent flag, and
  gradient finiteness (CI asserts both).

  python benchmarks/bench_diff.py            # full: n=1000/2000
  python benchmarks/bench_diff.py --quick    # CI smoke: n=200/300
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import merge_bench_json, record

RUN_TEMP_CAP = 4 << 30          # only execute backwards that fit in 4 GB


def _clouds(seed: int, n: int, d: int = 3):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, d)).astype(np.float32)


def _temp_bytes(fn, *args):
    """Compiled temp-buffer footprint of ``fn(*args)`` without running."""
    compiled = __import__("jax").jit(fn).lower(*args).compile()
    try:
        return int(compiled.memory_analysis().temp_size_in_bytes)
    except (AttributeError, TypeError):   # backend without the analysis
        return -1


def _timed_grad(fn, x):
    import jax

    g = jax.jit(jax.grad(fn))
    jax.block_until_ready(g(x))           # compile + warm
    t0 = time.time()
    out = g(x)
    jax.block_until_ready(out)
    return time.time() - t0, out


def bench_backward(results, quick: bool):
    import jax
    import jax.numpy as jnp

    import repro
    from repro.diff.unrolled import unrolled_value

    n = 200 if quick else 1000
    x = jnp.asarray(_clouds(0, n))
    y = jnp.asarray(_clouds(1, n))
    a = b = jnp.ones((n,), jnp.float32) / n
    key = jax.random.PRNGKey(0)

    # spar + lowrank: the paper's large-n families. (dense unrolled at
    # n=1000 is the 20 GB strawman — its footprint is recorded via the
    # lowrank/spar comparison already; running it would just OOM CI.)
    cases = {
        "spar_gw": (repro.SparGWSolver(epsilon=5e-2, s=8 * n,
                                       outer_iters=60, inner_iters=120,
                                       tol=0.0, inner_tol=0.0), True),
        "lowrank_gw": (repro.LowRankGWSolver(rank=4, outer_iters=150,
                                             inner_iters=100, tol=0.0,
                                             inner_tol=0.0), True),
    }
    for name, (solver, needs_key) in cases.items():
        if name == "lowrank_gw":
            def problem_of(x_):
                return repro.QuadraticProblem(
                    repro.Geometry.from_points(x_, a, validate=False),
                    repro.Geometry.from_points(y, b, validate=False),
                    validate=False)
        else:
            Cy = repro.Geometry.from_points(y, b).cost_matrix / 10.0

            def problem_of(x_):
                s2 = jnp.sum(x_ * x_, axis=1)
                Cx = jnp.maximum(s2[:, None] + s2[None, :]
                                 - 2.0 * x_ @ x_.T, 0.0) / 10.0
                return repro.QuadraticProblem(
                    repro.Geometry(Cx, a, validate=False),
                    repro.Geometry(Cy, b, validate=False), validate=False)

        kw = {"key": key} if needs_key else {}

        def implicit(x_):
            return repro.solve(problem_of(x_), solver, validate=False,
                               **kw).value

        def unrolled(x_):
            return unrolled_value(problem_of(x_), solver,
                                  key if needs_key else None)

        row = {"solver": name, "dataset": "diff", "n": n,
               "kind": "backward"}
        imp_mem = _temp_bytes(jax.grad(implicit), x)
        unr_mem = _temp_bytes(jax.grad(unrolled), x)
        imp_s, g = _timed_grad(implicit, x)
        row.update(implicit_s=round(imp_s, 4),
                   implicit_temp_bytes=imp_mem,
                   unrolled_temp_bytes=unr_mem,
                   grad_finite=bool(jnp.all(jnp.isfinite(g))))
        if 0 <= unr_mem <= RUN_TEMP_CAP:
            unr_s, _ = _timed_grad(unrolled, x)
            row.update(unrolled_s=round(unr_s, 4),
                       backward_speedup=round(unr_s / max(imp_s, 1e-9), 2))
        record(f"diff/backward/{name}/n{n}", imp_s * 1e6,
               f"imp_temp={imp_mem};unr_temp={unr_mem};"
               f"unr_s={row.get('unrolled_s', 'skipped')}")
        results.append(row)


def bench_lowrank_init(results, quick: bool):
    import jax
    import jax.numpy as jnp

    import repro

    n = 300 if quick else 2000
    x = jnp.asarray(_clouds(2, n))
    y = jnp.asarray(_clouds(3, n))
    a = b = jnp.ones((n,), jnp.float32) / n
    problem = repro.QuadraticProblem(repro.Geometry.from_points(x, a),
                                     repro.Geometry.from_points(y, b))
    key = jax.random.PRNGKey(7)
    vals = {}
    for init in ("anchors", "random"):
        solver = repro.LowRankGWSolver(init=init)     # default 300 steps
        t0 = time.time()
        out = repro.solve(problem, solver, key=key)
        jax.block_until_ready(out.value)
        sec = time.time() - t0
        vals[init] = float(out.value)
        record(f"diff/lowrank_init/{init}/n{n}", sec * 1e6,
               f"value={vals[init]:.6f};converged={bool(out.converged)}")
        results.append({
            "solver": "lowrank_gw", "dataset": "diff", "n": n,
            "kind": "lowrank_init", "init": init, "value": vals[init],
            "converged": bool(out.converged),
            "n_iters": int(out.n_iters), "wall_time_s": round(sec, 4)})
    # improvement of the structured init at the fixed 300-step budget
    results.append({
        "solver": "lowrank_gw", "dataset": "diff", "n": n,
        "kind": "lowrank_init_delta",
        "anchors_minus_random": round(vals["anchors"] - vals["random"], 6),
        "anchors_better": bool(vals["anchors"] <= vals["random"])})


def bench_barycenter(results, quick: bool):
    import jax
    import jax.numpy as jnp

    import repro
    from repro.diff import gw_barycenter

    n = 24 if quick else 48
    steps = 10 if quick else 25
    x1 = jnp.asarray(_clouds(4, n, 2))
    x2 = jnp.asarray(_clouds(5, n - 4, 2))
    solver = repro.DenseGWSolver(epsilon=5e-2, outer_iters=60,
                                 inner_iters=80, tol=0.0, inner_tol=0.0)
    t0 = time.time()
    res = gw_barycenter([x1, x2], n_points=n // 2,
                        key=jax.random.PRNGKey(2), solver=solver,
                        steps=steps, lr=0.05)
    sec = time.time() - t0
    objs = np.asarray(res.objectives, dtype=np.float64)
    monotone = bool(objs[-1] < objs[0])
    record(f"diff/barycenter/n{n}", sec * 1e6,
           f"obj0={objs[0]:.5f};objT={objs[-1]:.5f};descended={monotone}")
    results.append({
        "solver": "dense_gw", "dataset": "diff", "n": n,
        "kind": "barycenter", "steps": steps,
        "objective_first": float(objs[0]),
        "objective_last": float(objs[-1]),
        "objectives": [round(float(v), 6) for v in objs],
        "descended": monotone,
        "grad_finite": bool(np.all(np.isfinite(
            np.asarray(res.grad_norms)))),
        "wall_time_s": round(sec, 4)})


def main(quick: bool = False, json_path: str = "BENCH_PR10.json"):
    results = []
    bench_backward(results, quick)
    bench_lowrank_init(results, quick)
    bench_barycenter(results, quick)
    if json_path:
        merge_bench_json(json_path, "diff", results)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (n=200/300)")
    ap.add_argument("--json", default="BENCH_PR10.json",
                    help="merge records here ('' disables)")
    args = ap.parse_args()
    main(quick=args.quick, json_path=args.json)
