"""Fig. 5 (appendix C.1) + Table 1: Gaussian/Spiral datasets — error, CPU
time, live-buffer memory vs n, and empirical complexity slopes.

The paper's headline: SPAR-GW scales ~O(n² + s²) while EGW-family baselines
scale ~O(n³) (decomposable) / O(n⁴) (general); all methods are O(n²) memory.
We fit log-log slopes of measured runtimes as the empirical check.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FULL, live_device_bytes, record, timed
from benchmarks.datasets import DATASETS
from repro.core import pga_gw, spar_gw


def run(dataset: str):
    ns = [64, 128, 256, 512] if FULL else [48, 96, 192]
    times = {"pga_gw": [], "spar_gw": []}
    for n in ns:
        a, b, Cx, Cy = DATASETS[dataset](n)
        a, b = jnp.asarray(a), jnp.asarray(b)
        Cx, Cy = jnp.asarray(Cx), jnp.asarray(Cy)
        kw = dict(loss="l2", epsilon=1e-2, outer_iters=10, inner_iters=30)
        t_ref, (ref, _) = timed(lambda: pga_gw(a, b, Cx, Cy, **kw))
        mem = live_device_bytes()
        record(f"fig5/{dataset}/n{n}/pga_gw", t_ref * 1e6,
               f"value={float(ref):.5f};live_bytes={mem}")
        times["pga_gw"].append(t_ref)
        t_s, (v, _) = timed(
            lambda: spar_gw(jax.random.PRNGKey(0), a, b, Cx, Cy, s=16 * n,
                            **kw))
        mem = live_device_bytes()
        record(f"fig5/{dataset}/n{n}/spar_gw", t_s * 1e6,
               f"err={abs(float(v) - float(ref)):.5f};live_bytes={mem}")
        times["spar_gw"].append(t_s)
    for name, ts in times.items():
        slope = np.polyfit(np.log(ns), np.log(ts), 1)[0]
        record(f"fig5/{dataset}/slope/{name}", 0.0, f"loglog_slope={slope:.2f}")


def main():
    run("gaussian")
    run("spiral")


if __name__ == "__main__":
    main()
