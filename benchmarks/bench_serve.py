"""Serving-layer load generator — GWServer vs naive sequential solving.

Two workloads (DESIGN.md §9, EXPERIMENTS.md §Serving):

catalog  — a catalog-matching stream: every request compares one of a
           small set of recurring query geometries against one shared
           reference geometry. This is the cache-hot regime: padded
           device artifacts for both sides recur, so after one warm pass
           the GeometryCache serves ~every submit from cache and the
           bucketed executables are compiled. The server numbers are
           **steady state** (one untimed warm pass, then
           ``reset_stats()`` and a measured pass).

cold     — every request carries brand-new geometries (single pass on a
           fresh server, no warm-up). Latencies include the bucket
           compiles; this shows what bucketing alone buys when the cache
           can't help.

The sequential baseline replays the catalog stream through plain
``repro.solve`` calls in a cold process region — naive serving has no
warm phase, because with per-(m, n) compilation every new request shape
*is* a cold start. That compile-per-shape tail is exactly the failure
mode the bucketing layer removes, so the baseline keeps it.

Rows go to ``BENCH_PR7.json`` (dataset ``serve``) via
``common.merge_bench_json``; p50/p95/p99 come from the shared
``common.percentiles`` helper. ``--quick`` shrinks the stream for the CI
serve-smoke job (which asserts finite p99 and a nonzero catalog cache
hit rate — not the speedup, which is hardware-dependent).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import merge_bench_json, percentiles, record

JSON_PATH = "BENCH_PR7.json"


def _geom(n: int, seed: int):
    import jax.numpy as jnp

    import repro
    rng = np.random.default_rng(seed)
    pts = rng.standard_normal((n, 2)).astype(np.float32)
    C = np.sqrt(((pts[:, None] - pts[None]) ** 2).sum(-1)).astype(np.float32)
    w = np.full(n, 1.0 / n, np.float32)
    return repro.Geometry(jnp.asarray(C), jnp.asarray(w))


def catalog_stream(n_requests: int, sizes, n_queries: int):
    """Recurring query geometries vs one shared reference geometry."""
    import repro
    ref = _geom(32, seed=999)
    queries = [_geom(sizes[i % len(sizes)], seed=100 + i)
               for i in range(n_queries)]
    return [repro.QuadraticProblem(queries[i % n_queries], ref)
            for i in range(n_requests)]


def cold_stream(n_requests: int, sizes):
    """Every request is a brand-new geometry pair."""
    import repro
    return [repro.QuadraticProblem(_geom(sizes[i % len(sizes)], 500 + 2 * i),
                                   _geom(sizes[(i + 1) % len(sizes)],
                                         501 + 2 * i))
            for i in range(n_requests)]


def run_sequential(problems, solver):
    """Naive serving: one eager ``repro.solve`` per request, in order."""
    import repro
    lat = []
    t0 = time.perf_counter()
    for p in problems:
        t1 = time.perf_counter()
        out = repro.solve(p, solver)
        jax.block_until_ready(out.value)
        lat.append(time.perf_counter() - t1)
    return lat, time.perf_counter() - t0


def run_served(problems, solver, warm_passes: int = 1, config=None):
    """Submit the stream through a GWServer; returns per-request
    latencies, wall time, and the server's stats dict."""
    from repro.serve import GWServer, ServeConfig
    srv = GWServer(config or ServeConfig(max_batch=8, max_wait_s=60.0,
                                         on_failure="none"))
    for _ in range(warm_passes):
        srv.results([srv.submit(p, solver) for p in problems])
    srv.reset_stats()
    t0 = time.perf_counter()
    res = srv.results([srv.submit(p, solver) for p in problems])
    total = time.perf_counter() - t0
    return [r.latency_s for r in res], total, srv.stats()


_STAT_KEYS = ("n_batches", "mean_batch_lanes", "filler_lane_frac",
              "n_failed", "n_fallbacks", "cache_hits", "cache_misses",
              "cache_evictions", "cache_hit_rate")


def _row(workload: str, mode: str, lat_s, total_s: float,
         stats=None, speedup=None) -> dict:
    p = percentiles(lat_s)
    n = len(lat_s)
    rps = n / total_s if total_s > 0 else 0.0
    row = {
        "workload": workload,
        "mode": mode,
        "n_requests": n,
        "throughput_rps": round(rps, 3),
        "p50_ms": round(p["p50"] * 1e3, 3),
        "p95_ms": round(p["p95"] * 1e3, 3),
        "p99_ms": round(p["p99"] * 1e3, 3),
    }
    if stats is not None:
        row.update({k: (round(stats[k], 4) if isinstance(stats[k], float)
                        else stats[k]) for k in _STAT_KEYS})
    if speedup is not None:
        row["speedup_vs_sequential"] = round(speedup, 2)
    record(f"serve/{workload}/{mode}", (total_s / max(n, 1)) * 1e6,
           f"rps={rps:.2f};p50_ms={row['p50_ms']};p99_ms={row['p99_ms']}"
           + (f";hit_rate={stats['cache_hit_rate']:.3f}" if stats else "")
           + (f";speedup={row['speedup_vs_sequential']}"
              if speedup is not None else ""))
    return row


def main(quick: bool = False, json_path: str = JSON_PATH) -> list:
    import repro
    if quick:
        sizes, n_requests, n_queries = (12, 18, 28), 10, 3
    else:
        sizes = (12, 14, 18, 22, 26, 28, 30, 38, 44, 60)
        n_requests, n_queries = 64, 10
    solver = repro.get_solver("dense_gw").default_config(48)

    results = []
    catalog = catalog_stream(n_requests, sizes, n_queries)
    seq_lat, seq_total = run_sequential(catalog, solver)
    results.append(_row("catalog", "sequential", seq_lat, seq_total))
    srv_lat, srv_total, stats = run_served(catalog, solver, warm_passes=1)
    seq_rps = len(seq_lat) / seq_total
    srv_rps = len(srv_lat) / srv_total if srv_total > 0 else 0.0
    results.append(_row("catalog", "served", srv_lat, srv_total, stats,
                        speedup=srv_rps / seq_rps if seq_rps > 0 else 0.0))

    cold = cold_stream(n_requests, sizes)
    cold_lat, cold_total, cold_stats = run_served(cold, solver,
                                                  warm_passes=0)
    results.append(_row("cold", "served", cold_lat, cold_total, cold_stats))

    if json_path:
        merge_bench_json(json_path, "serve", results)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small stream")
    ap.add_argument("--json", default=JSON_PATH, metavar="PATH",
                    help="perf-trajectory JSON ('' disables)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(quick=args.quick, json_path=args.json)
