"""Fig. 6 (appendix A/C.2): fused GW — naive plan, dense FGW (benchmark),
SPAR-FGW. Attributes ~ N(0, 10 I5) vs N(5·1, 10 I5), α = 0.6."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FULL, record, timed
from benchmarks.datasets import DATASETS
from repro.core import spar_fgw
from repro.core.gw import dense_cost, fgw_dense


def _features(n, seed=0):
    rng = np.random.default_rng(seed)
    fx = rng.standard_normal((n, 5)) * np.sqrt(10)
    fy = rng.standard_normal((n, 5)) * np.sqrt(10) + 5.0
    M = np.sqrt(((fx[:, None] - fy[None, :]) ** 2).sum(-1))
    return jnp.asarray(M, jnp.float32)


def run(dataset: str, losses=("l2", "l1")):
    ns = [100, 200] if FULL else [60, 120]
    for loss in losses:
        for n in ns:
            a, b, Cx, Cy = DATASETS[dataset](n)
            a, b = jnp.asarray(a), jnp.asarray(b)
            Cx, Cy = jnp.asarray(Cx), jnp.asarray(Cy)
            M = _features(n)
            kw = dict(alpha=0.6, loss=loss, epsilon=1e-2, outer_iters=10,
                      inner_iters=30)
            t_ref, (ref, _) = timed(
                lambda: fgw_dense(a, b, Cx, Cy, M, **kw))
            record(f"fig6/{dataset}/{loss}/n{n}/fgw_dense", t_ref * 1e6,
                   f"value={float(ref):.5f}")
            # naive plan objective
            T0 = a[:, None] * b[None, :]
            v_naive = 0.6 * jnp.sum(dense_cost(Cx, Cy, T0, loss) * T0) \
                + 0.4 * jnp.sum(M * T0)
            record(f"fig6/{dataset}/{loss}/n{n}/naive", 0.0,
                   f"err={abs(float(v_naive) - float(ref)):.5f}")
            vals, t_acc = [], 0.0
            for r in range(3):
                t, (v, _) = timed(
                    lambda k: spar_fgw(k, a, b, Cx, Cy, M, s=16 * n, **kw),
                    jax.random.PRNGKey(r), warmup=(r == 0))
                vals.append(float(v))
                t_acc += t
            record(f"fig6/{dataset}/{loss}/n{n}/spar_fgw", t_acc / 3 * 1e6,
                   f"err={abs(np.mean(vals) - float(ref)):.5f}")


def main():
    run("moon")
    run("graph", losses=("l2",))


if __name__ == "__main__":
    main()
