"""Low-rank scaling benchmark: lowrank_gw vs spar_gw vs quantized_gw.

Wall-time and GW value over growing n on 3-D gaussian point clouds.
lowrank_gw runs on *point-cloud* geometries (its native regime: exact
rank-(d+2) cost factors, no n×n matrix anywhere); spar/quantized get the
same clouds as dense distance matrices. Solvers are dropped once they
stop being feasible on CPU (spar beyond ~2k; quantized beyond 5k unless
REPRO_BENCH_FULL=1 — its ~70 s n=10k run is the PR 3 reference the
low-rank solver is benchmarked against).

  python benchmarks/bench_lowrank.py            # n in {1k, 2k, 5k, 10k}
  python benchmarks/bench_lowrank.py --quick    # n=300 smoke
  REPRO_BENCH_FULL=1 python benchmarks/bench_lowrank.py  # + quantized@10k

Also appends its records to BENCH_PR4.json (--json '' disables).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import FULL, merge_bench_json, record

SPAR_MAX = 2000
QUANTIZED_MAX = 5000 if not FULL else 20_000


def clouds(seed: int, n: int, d: int = 3):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, d)).astype(np.float32)


def solvers_for(n: int):
    import repro
    out = {"lowrank_gw": repro.LowRankGWSolver()}
    if n <= QUANTIZED_MAX:
        out["quantized_gw"] = repro.QuantizedGWSolver()
    if n <= SPAR_MAX:
        out["spar_gw"] = repro.SparGWSolver(s=16 * n, inner_tol=1e-7,
                                            tol=1e-5)
    return out


def main(quick: bool = False, json_path: str = "BENCH_PR4.json"):
    import jax
    import jax.numpy as jnp

    import repro

    sizes = (300,) if quick else (1000, 2000, 5000, 10_000)
    key = jax.random.PRNGKey(0)
    results = []
    for n in sizes:
        x = jnp.asarray(clouds(0, n))
        y = jnp.asarray(clouds(1, n))
        a = b = jnp.ones((n,), jnp.float32) / n
        cloud_prob = repro.QuadraticProblem(repro.Geometry.from_points(x, a),
                                            repro.Geometry.from_points(y, b))
        dense_geoms = None
        for name, solver in solvers_for(n).items():
            if name == "lowrank_gw":
                problem = cloud_prob
            else:
                if dense_geoms is None:
                    dense_geoms = repro.QuadraticProblem(
                        repro.Geometry(cloud_prob.geom_x.cost_matrix, a),
                        repro.Geometry(cloud_prob.geom_y.cost_matrix, b))
                problem = dense_geoms
            t0 = time.time()
            out = repro.solve(problem, solver, key=key)
            jax.block_until_ready(out.value)
            sec = time.time() - t0
            record(f"lowrank/n{n}/{name}", sec * 1e6,
                   f"value={float(out.value):.5f};"
                   f"converged={bool(out.converged)}")
            results.append({
                "solver": name, "dataset": "gauss3d-lr", "loss": "l2",
                "n": n, "wall_time_s": round(sec, 6),
                "value": float(out.value),
                "converged": bool(out.converged),
                "n_iters": int(out.n_iters),
            })
        del cloud_prob, dense_geoms
    if json_path:
        merge_bench_json(json_path, "gauss3d-lr", results)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="n=300 smoke")
    ap.add_argument("--json", default="BENCH_PR4.json",
                    help="append records here ('' disables)")
    args = ap.parse_args()
    main(quick=args.quick, json_path=args.json)
