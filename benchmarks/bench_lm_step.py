"""LM substrate microbench: train_step / decode_step wall time for reduced
configs on CPU (1 device) — regression tracking for the framework layers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import FULL, record, timed
from repro.configs import base as cb
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import adamw

ARCHS = cb.ARCH_IDS if FULL else ("smollm_135m", "phi3_5_moe_42b_a6_6b",
                                  "xlstm_125m", "zamba2_7b")


def main():
    key = jax.random.PRNGKey(0)
    for arch in ARCHS:
        cfg = cb.get_reduced(arch)
        model = build_model(cfg)
        params = model.init(key)
        opt = adamw.init(params)
        B, S = 4, 64
        if cfg.n_codebooks > 1:
            toks = jax.random.randint(key, (B, S + 1, cfg.n_codebooks), 0,
                                      cfg.vocab_size)
        else:
            toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.random.normal(
                key, (B, cfg.n_image_tokens, cfg.d_model))
        step = jax.jit(make_train_step(model, act_dtype=jnp.float32,
                                       remat=False, total_steps=10))
        t, _ = timed(lambda: step(params, opt, batch), reps=3)
        record(f"lm_step/{arch}/train", t * 1e6, f"tokens={B*S}")

        cache = model.init_cache(B, S, dtype=jnp.float32)
        dec = jax.jit(lambda p, tk, c, i: model.decode_step(
            p, tk, c, i, act_dtype=jnp.float32,
            img=batch.get("image_embeds")))
        tok1 = batch["tokens"][:, :1]
        t, _ = timed(lambda: dec(params, tok1, cache, jnp.int32(0)), reps=5)
        record(f"lm_step/{arch}/decode", t * 1e6, "")


if __name__ == "__main__":
    main()
