"""Benchmark runner — one function per paper table/figure, plus a
registry-driven single-solver mode.

Prints ``name,us_per_call,derived`` CSV (harness contract). Set
REPRO_BENCH_FULL=1 for paper-scale sizes.

Modes:
  python benchmarks/run.py                      # full paper suite
  python benchmarks/run.py --solver spar_gw     # one registered solver
  python benchmarks/run.py --solver all         # every registered solver
(the --solver path benchmarks through repro.solve, so any solver added
via @register_solver is benchmarkable with no further CLI work).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def run_solver_mode(names, n: int, loss: str, reps: int) -> None:
    import repro
    from benchmarks.common import bench_solver

    if names == ["all"]:
        names = list(repro.available_solvers())
    unknown = [x for x in names if x not in repro.available_solvers()]
    if unknown:
        raise SystemExit(
            f"unknown solver(s) {unknown}; available: "
            f"{', '.join(repro.available_solvers())}")
    print("name,us_per_call,derived")
    for name in names:
        bench_solver(name, n=n, loss=loss, reps=reps)


def run_full_suite() -> None:
    from benchmarks import (
        bench_fig2,
        bench_fig3_ugw,
        bench_fig4_sensitivity,
        bench_fig5_scaling,
        bench_fig6_fgw,
        bench_grid_vs_coo,
        bench_lm_step,
        bench_spar_cost,
        bench_tables23_graphs,
    )
    print("name,us_per_call,derived")
    failures = []
    for mod in (bench_fig2, bench_fig3_ugw, bench_fig4_sensitivity,
                bench_fig5_scaling, bench_fig6_fgw, bench_grid_vs_coo,
                bench_spar_cost, bench_tables23_graphs, bench_lm_step):
        try:
            mod.main()
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures.append(mod.__name__)
    # roofline table (reads dry-run artifacts if present)
    try:
        from benchmarks import roofline
        roofline.main()
    except Exception:  # noqa: BLE001
        traceback.print_exc()
        failures.append("roofline")
    if failures:
        print("FAILED:", failures, file=sys.stderr)
        raise SystemExit(1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--solver", nargs="+", default=None, metavar="NAME",
                    help="benchmark the named registered solver(s) through "
                         "repro.solve ('all' = every registered solver); "
                         "omit for the full paper suite")
    ap.add_argument("--n", type=int, default=120, help="problem size")
    ap.add_argument("--loss", default="l2", help="ground loss")
    ap.add_argument("--reps", type=int, default=3, help="timing reps")
    args = ap.parse_args()
    if args.solver:
        run_solver_mode(args.solver, args.n, args.loss, args.reps)
    else:
        run_full_suite()


if __name__ == "__main__":
    main()
