"""Benchmark runner — one function per paper table/figure, plus a
registry-driven single-solver mode.

Prints ``name,us_per_call,derived`` CSV (harness contract). Set
REPRO_BENCH_FULL=1 for paper-scale sizes.

Modes:
  python benchmarks/run.py                      # full paper suite
  python benchmarks/run.py --solver spar_gw     # one registered solver
  python benchmarks/run.py --solver all         # every registered solver
  python benchmarks/run.py --solver quantized_gw --quick   # CI smoke
(the --solver path benchmarks through repro.solve, so any solver added
via @register_solver is benchmarkable with no further CLI work).

Solver mode also writes the machine-readable perf trajectory to
``BENCH_PR3.json`` (override with --json): one record per (solver, n)
with wall time, GW value, and convergence info, so per-PR perf history
is diffable instead of scraped from CSV logs.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def run_solver_mode(names, n: int, loss: str, reps: int,
                    json_path: str) -> None:
    import repro
    from benchmarks.common import bench_solver, merge_bench_json

    if names == ["all"]:
        names = list(repro.available_solvers())
    unknown = [x for x in names if x not in repro.available_solvers()]
    if unknown:
        raise SystemExit(
            f"unknown solver(s) {unknown}; available: "
            f"{', '.join(repro.available_solvers())}")
    print("name,us_per_call,derived")
    results = []
    failures = []
    for name in names:
        # a failing solver records a failure row and the suite moves on —
        # one broken rung must not abort the whole benchmark run
        try:
            sec, out, pcts, compile_s = bench_solver(name, n=n, loss=loss,
                                                     reps=reps)
        except Exception as exc:  # noqa: BLE001
            traceback.print_exc()
            failures.append(name)
            results.append({
                "solver": name,
                "dataset": "moon",
                "loss": loss,
                "n": n,
                "status": "failed",
                "error": f"{type(exc).__name__}: {exc}",
            })
            continue
        status = (out.status.describe() if out.status is not None
                  else "UNKNOWN")
        results.append({
            "solver": name,
            "dataset": "moon",
            "loss": loss,
            "n": n,
            "wall_time_s": round(sec, 6),
            "compile_s": round(compile_s, 6),
            "steady_s": round(sec, 6),
            "p50_s": round(pcts["p50"], 6),
            "p95_s": round(pcts["p95"], 6),
            "p99_s": round(pcts["p99"], 6),
            "value": float(out.value),
            "converged": bool(out.converged),
            "n_iters": int(out.n_iters),
            "status": status,
        })
    if json_path:
        merge_bench_json(json_path, "moon", results)
    if failures:
        print("FAILED:", failures, file=sys.stderr)
        raise SystemExit(1)


_SUITE = ("bench_fig2", "bench_fig3_ugw", "bench_fig4_sensitivity",
          "bench_fig5_scaling", "bench_fig6_fgw", "bench_grid_vs_coo",
          "bench_spar_cost", "bench_tables23_graphs", "bench_multiscale",
          "bench_lowrank", "bench_lm_step", "bench_serve", "bench_diff")


def run_full_suite() -> None:
    import importlib

    print("name,us_per_call,derived")
    failures = []
    for name in _SUITE:
        try:
            importlib.import_module(f"benchmarks.{name}").main()
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures.append(name)
    # roofline table (reads dry-run artifacts if present)
    try:
        from benchmarks import roofline
        roofline.main()
    except Exception:  # noqa: BLE001
        traceback.print_exc()
        failures.append("roofline")
    if failures:
        print("FAILED:", failures, file=sys.stderr)
        raise SystemExit(1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--solver", nargs="+", default=None, metavar="NAME",
                    help="benchmark the named registered solver(s) through "
                         "repro.solve ('all' = every registered solver); "
                         "omit for the full paper suite")
    ap.add_argument("--n", type=int, default=None,
                    help="problem size (default 120, or 60 with --quick)")
    ap.add_argument("--loss", default="l2", help="ground loss")
    ap.add_argument("--reps", type=int, default=None,
                    help="timing reps (default 3, or 1 with --quick)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke defaults: n=60, 1 rep (explicit --n/"
                         "--reps still win)")
    ap.add_argument("--json", default="BENCH_PR3.json", metavar="PATH",
                    help="machine-readable output for solver mode "
                         "('' disables)")
    args = ap.parse_args()
    if args.n is None:
        args.n = 60 if args.quick else 120
    if args.reps is None:
        args.reps = 1 if args.quick else 3
    if args.solver:
        run_solver_mode(args.solver, args.n, args.loss, args.reps, args.json)
    else:
        run_full_suite()


if __name__ == "__main__":
    main()
