"""Benchmark runner — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (harness contract). Set
REPRO_BENCH_FULL=1 for paper-scale sizes."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_fig2,
        bench_fig3_ugw,
        bench_fig4_sensitivity,
        bench_fig5_scaling,
        bench_fig6_fgw,
        bench_grid_vs_coo,
        bench_lm_step,
        bench_spar_cost,
        bench_tables23_graphs,
    )
    print("name,us_per_call,derived")
    failures = []
    for mod in (bench_fig2, bench_fig3_ugw, bench_fig4_sensitivity,
                bench_fig5_scaling, bench_fig6_fgw, bench_grid_vs_coo,
                bench_spar_cost, bench_tables23_graphs, bench_lm_step):
        try:
            mod.main()
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures.append(mod.__name__)
    # roofline table (reads dry-run artifacts if present)
    try:
        from benchmarks import roofline
        roofline.main()
    except Exception:  # noqa: BLE001
        traceback.print_exc()
        failures.append("roofline")
    if failures:
        print("FAILED:", failures, file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
