"""Synthetic datasets exactly following the paper's experimental setup
(§6.1, appendix C): Moon, Graph, Gaussian, Spiral."""
from __future__ import annotations

import numpy as np
import networkx as nx


def gaussian_weights(n: int, mean_frac: float, std_frac: float, rng):
    """Marginals ~ N(n*frac, n*std_frac) over point indices (paper: Moon
    uses N(n/3, n/20) and N(n/2, n/20))."""
    idx = np.arange(n)
    w = np.exp(-0.5 * ((idx - mean_frac * n) / (std_frac * n)) ** 2) + 1e-9
    return (w / w.sum()).astype(np.float32)


def _pairwise(x):
    d = np.sqrt(((x[:, None] - x[None, :]) ** 2).sum(-1))
    return d.astype(np.float32)


def make_moons_points(n, rng, noise=0.05):
    """Two interleaving half circles (sklearn.make_moons equivalent)."""
    n1 = n // 2
    n2 = n - n1
    t1 = np.pi * rng.random(n1)
    t2 = np.pi * rng.random(n2)
    outer = np.stack([np.cos(t1), np.sin(t1)], 1)
    inner = np.stack([1 - np.cos(t2), 0.5 - np.sin(t2)], 1)
    pts = np.concatenate([outer, inner], 0)
    return pts + noise * rng.standard_normal(pts.shape)


def moon(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    x = make_moons_points(n, rng)
    y = make_moons_points(n, np.random.default_rng(seed + 1))
    a = gaussian_weights(n, 1 / 3, 1 / 20, rng)
    b = gaussian_weights(n, 1 / 2, 1 / 20, rng)
    return a, b, _pairwise(x), _pairwise(y)


def graph(n: int, seed: int = 0, extra_p: float = 0.2):
    """Power-law graph; second graph adds random extra edges w.p. 0.2;
    marginals = degree distributions; relations = adjacency (paper §6.1)."""
    g1 = nx.barabasi_albert_graph(n, 3, seed=seed)
    A1 = nx.to_numpy_array(g1)
    rng = np.random.default_rng(seed)
    extra = (rng.random((n, n)) < extra_p).astype(float)
    extra = np.triu(extra, 1)
    A2 = np.clip(A1 + extra + extra.T, 0, 1)
    d1 = A1.sum(1) + 1e-9
    d2 = A2.sum(1) + 1e-9
    return ((d1 / d1.sum()).astype(np.float32),
            (d2 / d2.sum()).astype(np.float32),
            A1.astype(np.float32), A2.astype(np.float32))


def gaussian_mixture(n: int, seed: int = 0):
    """Source: 3-component mixture in R^5; target: 2-component in R^10
    (appendix C.1, heterogeneous spaces)."""
    rng = np.random.default_rng(seed)
    cov_s = 0.6 ** np.abs(np.subtract.outer(np.arange(5), np.arange(5)))
    mus = [np.zeros(5), np.ones(5), np.array([0, 2, 2, 0, 0.0])]
    comp = rng.integers(0, 3, n)
    xs = np.stack([rng.multivariate_normal(mus[c], cov_s) for c in comp])
    mut = [0.5 * np.ones(10), 2 * np.ones(10)]
    comp_t = rng.integers(0, 2, n)
    xt = np.stack([rng.multivariate_normal(mut[c], np.eye(10))
                   for c in comp_t])
    a = gaussian_weights(n, 1 / 3, 1 / 20, rng)
    b = gaussian_weights(n, 1 / 2, 1 / 20, rng)
    return a, b, _pairwise(xs), _pairwise(xt)


def spiral(n: int, seed: int = 0):
    """Two noisy spirals, the second rotated pi/4 + translated (appendix C.1)."""
    rng = np.random.default_rng(seed)
    r = rng.random(n)
    u = rng.random(n)
    u2 = rng.random(n)
    ang = 3 * np.pi * np.sqrt(r)
    xs = np.stack([-ang * np.cos(ang) + u, ang * np.sin(ang) + u2], 1) \
        - np.array([10.0, 10.0])
    th = np.pi / 4
    R = np.array([[np.cos(th), -np.sin(th)], [np.sin(th), np.cos(th)]])
    xt = xs @ R.T + 2 * np.array([10.0, 10.0])
    a = gaussian_weights(n, 1 / 3, 1 / 20, rng)
    b = gaussian_weights(n, 1 / 2, 1 / 20, rng)
    return a, b, _pairwise(xs), _pairwise(xt)


DATASETS = {"moon": moon, "graph": graph, "gaussian": gaussian_mixture,
            "spiral": spiral}
