"""COO spar_cost impl shoot-out: jnp ``lax.map`` baseline vs the fused
Pallas path vs the materialized-support fast mode (kernels/spar_cost).

Two views per (n, s) cell:
  * per-iteration cost-assembly call (the O(s²) hot path in isolation) —
    steady-state, support setup hoisted exactly as in the solvers;
  * end-to-end ``spar_gw`` (materialization amortized over outer_iters).

Also exercises the dispatch micro-autotune hook (block-size sweep for the
materialized matvec kernel) and dumps the records to artifacts/autotune/
for ``benchmarks/roofline.py`` to report.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

import repro
from benchmarks.common import FULL, record, timed
from benchmarks.datasets import moon
from repro.core import sampling
from repro.kernels import dispatch
from repro.kernels.spar_cost.ops import make_spar_cost_fn, spar_matvec
from repro.kernels.spar_cost.ref import materialize_loss

IMPLS = ("jnp", "pallas", "materialized")


def _support(key, a, b, Cx, Cy, s):
    probs = sampling.balanced_probs(a, b)
    rows, cols = sampling.sample_pairs(key, probs, s)
    t = a[rows] * b[cols]
    return rows, cols, t


def bench_cell(n: int, ratio: int, reps: int, loss: str = "l2"):
    s = ratio * n
    a, b, Cx, Cy = moon(n)
    a, b = jnp.asarray(a), jnp.asarray(b)
    Cx, Cy = jnp.asarray(Cx), jnp.asarray(Cy)
    key = jax.random.PRNGKey(0)
    rows, cols, t = _support(key, a, b, Cx, Cy, s)

    times = {}
    # --- per-iteration cost assembly (support setup hoisted, as in solvers)
    for impl in IMPLS:
        cost_fn = make_spar_cost_fn(Cx, Cy, rows, cols, loss, impl=impl,
                                    chunk=1024)
        f = jax.jit(lambda tv, off: cost_fn(tv, off))
        sec, out = timed(f, t, jnp.zeros((s,)), reps=reps)
        assert bool(jnp.isfinite(out).all())
        times[impl] = sec
        record(f"spar_cost/n{n}/s{ratio}n/{impl}", sec * 1e6)
    base = times["jnp"]
    for impl in ("pallas", "materialized"):
        record(f"spar_cost/n{n}/s{ratio}n/{impl}_speedup",
               times[impl] * 1e6, f"x{base / max(times[impl], 1e-12):.2f}")

    # --- end-to-end solver wall-clock (compiled path per impl, paper
    # defaults: 20 outer iterations amortize the one-time materialization)
    problem = repro.QuadraticProblem(repro.Geometry(Cx, a),
                                     repro.Geometry(Cy, b), loss=loss)
    gw_times = {}
    for impl in IMPLS:
        solver = repro.SparGWSolver(s=s, epsilon=1e-2, outer_iters=20,
                                    inner_iters=50, cost_impl=impl)
        sec, out = timed(
            lambda k, solver=solver: repro.solve(problem, solver, key=k,
                                                 validate=False),
            key, reps=max(reps // 2, 1))
        gw_times[impl] = sec
        record(f"spar_gw/n{n}/s{ratio}n/{impl}", sec * 1e6,
               f"value={float(out.value):.5f}")
    base = gw_times["jnp"]
    record(f"spar_gw/n{n}/s{ratio}n/best_speedup",
           min(gw_times.values()) * 1e6,
           f"x{base / max(min(gw_times.values()), 1e-12):.2f}")
    return times, gw_times


def tune_matvec_block(n: int, ratio: int):
    """Dispatch micro-autotune demo: block sweep for the matvec kernel."""
    s = ratio * n
    a, b, Cx, Cy = moon(n)
    a, b = jnp.asarray(a), jnp.asarray(b)
    Cx, Cy = jnp.asarray(Cx), jnp.asarray(Cy)
    rows, cols, t = _support(jax.random.PRNGKey(1), a, b, Cx, Cy, s)
    Lmat = materialize_loss(Cx, Cy, rows, cols, "l2")
    reps = 2 if dispatch.backend() == "tpu" else 1   # interpret mode is slow
    # one matvec reads the (s, s) loss matrix once and does 2s² flops —
    # the analytic counts that place the winner on the roofline
    best = dispatch.autotune(
        "spar_cost", (64, 128, 256),
        lambda blk: spar_matvec(Lmat, t, block=blk), reps=reps,
        flops_per_call=2.0 * s * s,
        bytes_per_call=4.0 * s * s)
    if best is not None:
        record(f"spar_cost/autotune/n{n}/s{ratio}n", 0.0, f"block={best}")
    path = dispatch.dump_autotune_records()
    if path is not None:
        record("spar_cost/autotune/dump", 0.0, str(path))


def main(quick: bool = False):
    n = 200 if (FULL or not quick) else 64
    reps = 10 if FULL else (2 if quick else 6)
    ratios = (4,) if quick else (4, 16)
    for ratio in ratios:
        bench_cell(n, ratio, reps)
    tune_matvec_block(n, ratios[0])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes / few reps (CI smoke)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(quick=args.quick)
