"""Fig. 4: sensitivity of SPAR-GW to subsample size s and regularization ε.
n fixed; s ∈ {2,4,8,16,32}×n, ε ∈ {5^0 … 5^-4} (paper §6.1.2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FULL, record, timed
from benchmarks.datasets import moon
from repro.core import spar_gw


def main():
    n = 200 if FULL else 100
    a, b, Cx, Cy = moon(n)
    a, b = jnp.asarray(a), jnp.asarray(b)
    Cx, Cy = jnp.asarray(Cx), jnp.asarray(Cy)
    for ratio in (2, 4, 8, 16, 32):
        for eps in (1.0, 0.2, 0.04, 0.008, 0.0016):
            vals, t_acc = [], 0.0
            for r in range(3):
                t, (v, _) = timed(
                    lambda k: spar_gw(k, a, b, Cx, Cy, s=ratio * n,
                                      loss="l2", epsilon=eps,
                                      outer_iters=10, inner_iters=30),
                    jax.random.PRNGKey(r), warmup=(r == 0))
                vals.append(float(v))
                t_acc += t
            record(f"fig4/s{ratio}n/eps{eps}", t_acc / 3 * 1e6,
                   f"value={np.mean(vals):.5f};std={np.std(vals):.5f}")


if __name__ == "__main__":
    main()
