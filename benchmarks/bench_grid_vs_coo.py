"""Beyond-paper validation: grid (factorized) vs COO sampling at equal
budget — estimator mean/variance and runtime. The grid variant's pairwise
dependence costs a constant variance factor; this bench measures it."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FULL, record, timed
from benchmarks.datasets import moon
from repro.core import grid_spar_gw, pga_gw, spar_gw


def main():
    n = 200 if FULL else 100
    reps = 10 if FULL else 6
    a, b, Cx, Cy = moon(n)
    a, b = jnp.asarray(a), jnp.asarray(b)
    Cx, Cy = jnp.asarray(Cx), jnp.asarray(Cy)
    kw = dict(loss="l2", epsilon=1e-2, outer_iters=10, inner_iters=30)
    _, (ref, _) = timed(lambda: pga_gw(a, b, Cx, Cy, **kw))
    for ratio in (4, 16):
        s = ratio * n
        side = int(np.sqrt(s))
        coo_vals, grid_vals = [], []
        t_coo = t_grid = 0.0
        for r in range(reps):
            t, (v, _) = timed(lambda k: spar_gw(k, a, b, Cx, Cy, s=s, **kw),
                              jax.random.PRNGKey(r), warmup=(r == 0))
            coo_vals.append(float(v)); t_coo += t
            t, (v, _) = timed(lambda k: grid_spar_gw(k, a, b, Cx, Cy,
                                                     s_r=side, s_c=side, **kw),
                              jax.random.PRNGKey(100 + r), warmup=(r == 0))
            grid_vals.append(float(v)); t_grid += t
        record(f"grid_vs_coo/s{ratio}n/coo", t_coo / reps * 1e6,
               f"bias={np.mean(coo_vals)-float(ref):.5f};"
               f"std={np.std(coo_vals):.5f}")
        record(f"grid_vs_coo/s{ratio}n/grid", t_grid / reps * 1e6,
               f"bias={np.mean(grid_vals)-float(ref):.5f};"
               f"std={np.std(grid_vals):.5f}")


if __name__ == "__main__":
    main()
