"""Shared benchmark harness utilities. CSV contract: name,us_per_call,derived."""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import numpy as np

# shared percentile helper (p50/p95/p99) — single definition for every
# BENCH_*.json writer, so serve-layer and solver rows report the same
# tail statistics
from repro.obs import percentiles, span  # noqa: F401  (re-export)

FULL = bool(os.environ.get("REPRO_BENCH_FULL"))

_rows = []


def record(name: str, us_per_call: float, derived: str = ""):
    _rows.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def rows():
    return list(_rows)


def merge_bench_json(path: str, dataset: str, results: list) -> None:
    """Merge ``results`` into the perf-trajectory JSON at ``path``.

    Each writer owns one ``dataset`` namespace: its previous records are
    replaced, every other writer's records are preserved, so run.py and
    bench_multiscale.py can share one diffable BENCH_PR3.json.
    """
    merged = {"schema": "bench-pr3-v1", "results": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f)
        except (json.JSONDecodeError, OSError):
            pass
    merged["results"] = [r for r in merged.get("results", [])
                         if r.get("dataset") != dataset] + results
    with open(path, "w") as f:
        json.dump(merged, f, indent=2)
    print(f"wrote {path} (+{len(results)} {dataset} records)",
          file=sys.stderr)


def timed_samples(fn, *args, reps: int = 1, warmup: bool = True):
    """Per-rep wall times of ``fn`` (blocks on jax outputs each rep).

    Returns ``(samples, last_result)`` where ``samples`` is a list of
    ``reps`` individual call durations in seconds — feed it to
    :func:`percentiles` for p50/p95/p99. ``warmup=True`` runs one
    untimed call first so compilation never lands in the samples.
    """
    if warmup:
        # the warmup call is where jit compilation lands; the span makes
        # compile time visible in obs.report() without polluting samples
        with span("bench.compile"):
            out = fn(*args)
            jax.block_until_ready(out)
    samples = []
    for _ in range(reps):
        t0 = time.time()
        out = fn(*args)
        jax.block_until_ready(out)
        samples.append(time.time() - t0)
    return samples, out


def timed(fn, *args, reps: int = 1, warmup: bool = True):
    """Wall-time fn; blocks on jax outputs. Returns (seconds, last_result)."""
    samples, out = timed_samples(fn, *args, reps=reps, warmup=warmup)
    return sum(samples) / len(samples), out


def live_device_bytes() -> int:
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize
               for a in jax.live_arrays())


def bench_solver(name: str, n: int = 120, loss: str = "l2", reps: int = 3,
                 dataset: str = "moon", **solver_kw):
    """Benchmark any registered solver through the unified API.

    One code path for every solver in the registry (`--solver` in run.py):
    builds a problem from ``dataset``, instantiates the solver via its
    ``default_config(n)`` (overridable with ``solver_kw``), and records
    steady-state ``repro.solve`` wall time + value/convergence info.
    """
    import dataclasses

    import jax.numpy as jnp

    import repro
    from benchmarks.datasets import DATASETS

    a, b, Cx, Cy = map(jnp.asarray, DATASETS[dataset](n))
    problem = repro.QuadraticProblem(repro.Geometry(Cx, a),
                                     repro.Geometry(Cy, b), loss=loss)
    solver = repro.get_solver(name).default_config(n)
    if solver_kw:
        solver = dataclasses.replace(solver, **solver_kw)
    key = jax.random.PRNGKey(0)
    fn = lambda: repro.solve(problem, solver, key=key)  # noqa: E731
    # explicit warmup under a span so the compile/steady split survives
    # into obs.report() and the BENCH json rows
    with span("bench.compile", solver=name) as sp:
        jax.block_until_ready(fn())
    samples, out = timed_samples(fn, reps=reps, warmup=False)
    compile_s = sp["duration_s"]
    sec = sum(samples) / len(samples)
    pcts = percentiles(samples)
    status = out.status.describe() if out.status is not None else "UNKNOWN"
    record(f"solve/{dataset}/{loss}/n{n}/{name}", sec * 1e6,
           f"value={float(out.value):.5f};n_iters={int(out.n_iters)};"
           f"converged={bool(out.converged)};status={status};"
           f"p50_us={pcts['p50'] * 1e6:.1f};p99_us={pcts['p99'] * 1e6:.1f};"
           f"compile_s={compile_s:.3f}")
    return sec, out, pcts, compile_s
