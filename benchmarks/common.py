"""Shared benchmark harness utilities. CSV contract: name,us_per_call,derived."""
from __future__ import annotations

import os
import time

import jax
import numpy as np

FULL = bool(os.environ.get("REPRO_BENCH_FULL"))

_rows = []


def record(name: str, us_per_call: float, derived: str = ""):
    _rows.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def rows():
    return list(_rows)


def timed(fn, *args, reps: int = 1, warmup: bool = True):
    """Wall-time fn; blocks on jax outputs. Returns (seconds, last_result)."""
    if warmup:
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / reps, out


def live_device_bytes() -> int:
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize
               for a in jax.live_arrays())
