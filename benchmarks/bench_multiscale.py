"""Multiscale scaling benchmark: quantized_gw vs spar_gw vs dense_gw.

Wall-time and GW value per solver over growing n on 3-D gaussian point
clouds, with each solver dropped once it stops being feasible on CPU
(dense beyond ~1k, spar beyond ~2k; quantized runs to 20k under
REPRO_BENCH_FULL=1). Cost matrices are built chunked in float32 so the
20k case stays within a couple of GB.

  python benchmarks/bench_multiscale.py            # n up to 2000
  python benchmarks/bench_multiscale.py --quick    # n=300 smoke
  REPRO_BENCH_FULL=1 python benchmarks/bench_multiscale.py   # n to 20k

Also appends its records to BENCH_PR3.json (--json '' disables).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import FULL, merge_bench_json, record

DENSE_MAX = 1000
SPAR_MAX = 2000


def cloud_dists(seed: int, n: int, d: int = 3, chunk: int = 2048):
    """(n, n) float32 euclidean distance matrix, chunked (no n² float64)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    sq = (x ** 2).sum(1)
    D = np.empty((n, n), np.float32)
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        g = sq[lo:hi, None] + sq[None, :] - 2.0 * (x[lo:hi] @ x.T)
        D[lo:hi] = np.sqrt(np.maximum(g, 0.0))
    return D


def solvers_for(n: int):
    import repro
    out = {"quantized_gw": repro.QuantizedGWSolver()}
    if n <= SPAR_MAX:
        out["spar_gw"] = repro.SparGWSolver(s=16 * n, inner_tol=1e-7,
                                            tol=1e-5)
    if n <= DENSE_MAX:
        out["dense_gw"] = repro.DenseGWSolver(inner_iters=500,
                                              inner_tol=1e-7, tol=1e-5)
    return out


def main(quick: bool = False, json_path: str = "BENCH_PR3.json"):
    import jax
    import jax.numpy as jnp

    import repro

    if quick:
        sizes = (300,)
    elif FULL:
        sizes = (1000, 2000, 5000, 10_000, 20_000)
    else:
        sizes = (500, 1000, 2000)
    key = jax.random.PRNGKey(0)
    results = []
    for n in sizes:
        Cx = jnp.asarray(cloud_dists(0, n))
        Cy = jnp.asarray(cloud_dists(1, n))
        a = b = jnp.ones((n,), jnp.float32) / n
        problem = repro.QuadraticProblem(repro.Geometry(Cx, a),
                                         repro.Geometry(Cy, b))
        for name, solver in solvers_for(n).items():
            t0 = time.time()
            out = repro.solve(problem, solver, key=key)
            jax.block_until_ready(out.value)
            sec = time.time() - t0
            record(f"multiscale/n{n}/{name}", sec * 1e6,
                   f"value={float(out.value):.5f};"
                   f"converged={bool(out.converged)}")
            results.append({
                "solver": name, "dataset": "gauss3d", "loss": "l2", "n": n,
                "wall_time_s": round(sec, 6), "value": float(out.value),
                "converged": bool(out.converged),
                "n_iters": int(out.n_iters),
            })
        del Cx, Cy, problem
    if json_path:
        merge_bench_json(json_path, "gauss3d", results)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="n=300 smoke")
    ap.add_argument("--json", default="BENCH_PR3.json",
                    help="append records here ('' disables)")
    args = ap.parse_args()
    main(quick=args.quick, json_path=args.json)
